"""Graph analytics sweep — the paper's six algorithms on all three
workloads with the platform models; a compact reproduction of Fig. 5/6.

One ``GraphProcessor`` session per graph (via benchmarks.common): all six
algorithms and both engine modes share each graph's cached plans.

  PYTHONPATH=src python examples/graph_analytics.py [--scale 0.004]
"""

import argparse
import sys

sys.path.insert(0, ".")  # allow running from repo root

from benchmarks import common  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=1 / 512)
    args = ap.parse_args()
    graphs = common.load_graphs(args.scale)
    hdr = (f"{'graph':5s} {'algo':9s} {'NALE cyc':>11s} {'CPU cyc':>11s} "
           f"{'GPU cyc':>11s} {'vsCPU':>7s} {'perf/W vs GPU':>14s}")
    print(hdr)
    print("-" * len(hdr))
    for gname, g in graphs.items():
        for algo in common.ALGOS:
            rep = common.platform_reports(g, algo)
            nale, cpu, gpu = rep["nale"], rep["cpu"], rep["gpu"]
            print(f"{gname:5s} {algo:9s} {nale.cycles:11.3g} "
                  f"{cpu.cycles:11.3g} {gpu.cycles:11.3g} "
                  f"{cpu.time_s/nale.time_s:6.1f}x "
                  f"{nale.perf_per_watt/gpu.perf_per_watt:13.1f}x")
    info = common.service().store.stats()
    print(f"plan store: {info['plans']} cached plans, hit rate "
          f"{info['hit_rate']:.1%} across all graphs/algorithms/modes "
          f"above")


if __name__ == "__main__":
    main()
