"""Algorithm-catalog tour — the ``AlgorithmSpec`` registry end to end.

Part 1 walks the registry: every registered algorithm with its semiring,
update rule, and async-eligibility, then runs the four PR-9 families
(pagerank_delta / cc / kcore / tricount) on one graph through several
engine flavors — including delta-form PageRank on the self-timed
distributed engine (``dist_flavor="async"``), which the classic
accumulation form cannot use.

Part 2 registers a NEW algorithm from scratch — best-reliability paths
over a custom max-times semiring — and runs it through the same
``GraphProcessor.run(QuerySpec)`` front door with zero engine edits.

  PYTHONPATH=src python examples/algorithms.py
"""

import sys

sys.path.insert(0, ".")  # allow running from repo root

import numpy as np  # noqa: E402

import jax.numpy as jnp  # noqa: E402

from repro import api  # noqa: E402
from repro.core import graph as G  # noqa: E402
from repro.core import semiring as S  # noqa: E402


def tour_catalog(proc):
    print("== registered algorithms ==")
    hdr = (f"{'algorithm':14s} {'semiring':14s} {'update':15s} "
           f"{'async-eligible':>14s} {'dist-async':>10s}")
    print(hdr)
    print("-" * len(hdr))
    for name in api.registered_algorithms():
        a = api.get_algorithm(name)
        if a.runner is not None:
            print(f"{name:14s} {'—':14s} {'(one-shot/host)':15s} "
                  f"{'—':>14s} {'—':>10s}")
            continue
        rule = S.rule(a.update)
        print(f"{name:14s} {a.semiring:14s} {a.update:15s} "
              f"{'yes':>14s} {'yes' if rule.monotone else 'no':>10s}")

    print("\n== the PR-9 families across engine flavors ==")
    flavors = {
        "sync": api.ExecutionPolicy(mode="sync"),
        "async": api.ExecutionPolicy(mode="async"),
        "dist-async(k=2)": api.ExecutionPolicy(
            mode="distributed", dist_flavor="async", local_sweeps=2),
    }
    for fname, pol in flavors.items():
        r = proc.pagerank_delta(policy=pol.but(tol=1e-9, max_sweeps=2000))
        top = int(np.argmax(np.asarray(r.values)))
        print(f"pagerank_delta [{fname:15s}] top vertex {top:4d} "
              f"mass {float(np.asarray(r.values)[top]):.5f} "
              f"sweeps {r.stats.sweeps}")
    r = proc.run(api.QuerySpec(algo="cc"))
    ncomp = len(np.unique(np.asarray(r.values)))
    print(f"cc             components: {ncomp}")
    for k in (2, 3):
        r = proc.kcore(k)
        print(f"kcore k={k}      members: "
              f"{int(np.asarray(r.values).sum())}/{proc.g.n}")
    r = proc.tricount()
    print(f"tricount       triangles: {r.extra['triangles']} "
          f"(max per-vertex {int(np.asarray(r.values).max())})")

    print("\nclassic pagerank on the self-timed distributed engine "
          "(order-sensitive — rejected):")
    try:
        proc.run(api.QuerySpec(algo="pagerank", policy=flavors[
            "dist-async(k=2)"]))
    except ValueError as e:
        print(f"  ValueError: {e}")


def register_reliability():
    """A new algorithm = a semiring + an AlgorithmSpec. Nothing else."""
    if "max_times" not in S.SEMIRINGS:
        S.register(S.Semiring(
            name="max_times",          # ⊕ = max, ⊗ = × over [0, 1]
            add=jnp.maximum,
            mul=jnp.multiply,
            zero=0.0,                  # absorbs under ⊗ — the contract
            one=1.0,
            improves=lambda new, old: new > old,
            reduce_fn=lambda x, axis=None: jnp.max(x, axis=axis),
        ))
    if "reliability" not in api.registered_algorithms():
        api.register_algorithm(api.AlgorithmSpec(
            name="reliability",
            semiring="max_times",
            update="relax",            # idempotent ⇒ every flavor eligible
            source_required=True,
            coalescible=True,
            init=lambda p, src, pol: np.where(
                np.arange(p.n) == src, 1.0, 0.0).astype(np.float32),
            default_policy=(("max_sweeps", 10_000),),
        ))


def main():
    g = G.rmat(400, 2400, seed=3)
    proc = api.GraphProcessor(g, b=16, num_clusters=16)
    tour_catalog(proc)

    print("\n== registering a custom algorithm: best-reliability paths ==")
    register_reliability()
    # reuse the same session: weights squashed into (0, 1] probabilities
    gp = G.Graph(n=g.n, indptr=g.indptr, indices=g.indices,
                 weights=(1.0 / (1.0 + g.weights)).astype(np.float32))
    proc2 = api.GraphProcessor(gp, b=16, num_clusters=16)
    for mode in ("sync", "async"):
        r = proc2.run(api.QuerySpec(
            algo="reliability", sources=(0,),
            policy=api.ExecutionPolicy(mode=mode)))
        v = np.asarray(r.values)
        reach = int((v > 0).sum())
        print(f"reliability [{mode:5s}] reachable {reach}/{gp.n}, "
              f"best non-source path p={float(np.sort(v)[-2]):.4f}, "
              f"sweeps {r.stats.sweeps}")


if __name__ == "__main__":
    main()
