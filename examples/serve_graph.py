"""GraphService: serve many graphs, coalesce queries, survive restarts.

Three serving-layer behaviours on top of the session API:

  1. multi-graph registry — one service front door, one shared plan
     store (byte-bounded LRU) for every registered graph;
  2. request coalescing — concurrent single-source SSSP/BFS submits
     that resolve to the same plan run as ONE batched vmap execution;
  3. warm restart — a second service instance (a "new process") serves
     its first query from the persistent on-disk plan cache with zero
     clustering/BSR-build work.

  PYTHONPATH=src python examples/serve_graph.py
"""

import tempfile
import time

import numpy as np

from repro import api
from repro.core import graph as G

cache_dir = tempfile.mkdtemp(prefix="repro-plan-cache-")
roads = G.make_paper_graph("ca", scale=1 / 512, seed=0)
social = G.make_paper_graph("fb", scale=1 / 512, seed=0)

# 1. one gateway, many graphs ------------------------------------------------
svc = api.GraphService(cache_dir=cache_dir, max_plan_bytes=2 << 30)
svc.register("roads", roads, b=16, num_clusters=64)
svc.register("social", social, b=16, num_clusters=64)
print(f"registered graphs: {svc.graphs()}")

# 2. coalescing front door: 8 tickets, ONE batched run per (graph, plan) -----
tickets = {s: svc.submit("roads", api.QuerySpec(algo="sssp", sources=(s,)))
           for s in range(0, 8)}
t_pr = svc.submit("social", api.QuerySpec(algo="pagerank"))
t0 = time.time()
out = svc.gather()
print(f"\ngather: {len(out)} results in {time.time() - t0:.2f}s; "
      f"SSSP tickets shared one batched run "
      f"(coalesced={out[tickets[0]].extra['coalesced']})")
solo = svc.run("roads", api.QuerySpec(algo="sssp", sources=(3,)))
assert np.array_equal(out[tickets[3]].values, solo.values)
print("coalesced values are bit-identical to a sequential run() call")
print(f"service stats: {svc.stats()['coalesced_queries']} queries over "
      f"{svc.stats()['batched_runs']} batched runs; plan store "
      f"{svc.store.stats()['plans']} plans, "
      f"{svc.store.stats()['bytes'] / 1e6:.1f} MB")

# 3. warm restart: a NEW service instance loads plans from disk --------------
t0 = time.time()
cold_builds = svc.store.stats()["misses"]
svc2 = api.GraphService(cache_dir=cache_dir, max_plan_bytes=2 << 30)
proc2 = svc2.register("roads", roads, b=16, num_clusters=64)
r = svc2.run("roads", api.QuerySpec(algo="sssp", sources=(0,)))
warm = time.time() - t0
st = svc2.store.stats()
print(f"\nwarm restart: first query in {warm:.2f}s with "
      f"{proc2._prepare_calls} compile-pipeline runs "
      f"({st['disk_hits']} plan(s) loaded from disk; cold process "
      f"needed {cold_builds} builds)")
assert proc2._prepare_calls == 0
np.testing.assert_array_equal(
    r.values, out[tickets[0]].values)
print("warm values match the cold run exactly")
