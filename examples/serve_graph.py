"""GraphService + GraphServer: serve many graphs AND many clients,
coalesce queries, survive restarts.

Serving-layer behaviours on top of the session API:

  1. multi-graph registry — one service front door, one shared plan
     store (byte-bounded LRU) for every registered graph;
  2. request coalescing — concurrent single-source SSSP/BFS submits
     that resolve to the same plan run as ONE batched vmap execution;
  3. warm restart — a second service instance (a "new process") serves
     its first query from the persistent on-disk plan cache with zero
     clustering/BSR-build work;
  4. concurrent clients — a GraphServer whose background wave scheduler
     continuously batches requests from many threads (deadlines,
     backpressure, plan warming included).

  PYTHONPATH=src python examples/serve_graph.py
"""

import tempfile
import threading
import time

import numpy as np

from repro import api
from repro.core import graph as G

cache_dir = tempfile.mkdtemp(prefix="repro-plan-cache-")
roads = G.make_paper_graph("ca", scale=1 / 512, seed=0)
social = G.make_paper_graph("fb", scale=1 / 512, seed=0)

# 1. one gateway, many graphs ------------------------------------------------
svc = api.GraphService(cache_dir=cache_dir, max_plan_bytes=2 << 30)
svc.register("roads", roads, b=16, num_clusters=64)
svc.register("social", social, b=16, num_clusters=64)
print(f"registered graphs: {svc.graphs()}")

# 2. coalescing front door: 8 tickets, ONE batched run per (graph, plan) -----
tickets = {s: svc.submit("roads", api.QuerySpec(algo="sssp", sources=(s,)))
           for s in range(0, 8)}
t_pr = svc.submit("social", api.QuerySpec(algo="pagerank"))
t0 = time.time()
out = svc.gather()
print(f"\ngather: {len(out)} results in {time.time() - t0:.2f}s; "
      f"SSSP tickets shared one batched run "
      f"(coalesced={out[tickets[0]].extra['coalesced']})")
solo = svc.run("roads", api.QuerySpec(algo="sssp", sources=(3,)))
assert np.array_equal(out[tickets[3]].values, solo.values)
print("coalesced values are bit-identical to a sequential run() call")
print(f"service stats: {svc.stats()['coalesced_queries']} queries over "
      f"{svc.stats()['batched_runs']} batched runs; plan store "
      f"{svc.store.stats()['plans']} plans, "
      f"{svc.store.stats()['bytes'] / 1e6:.1f} MB")

# 3. warm restart: a NEW service instance loads plans from disk --------------
t0 = time.time()
cold_builds = svc.store.stats()["misses"]
svc2 = api.GraphService(cache_dir=cache_dir, max_plan_bytes=2 << 30)
proc2 = svc2.register("roads", roads, b=16, num_clusters=64)
r = svc2.run("roads", api.QuerySpec(algo="sssp", sources=(0,)))
warm = time.time() - t0
st = svc2.store.stats()
print(f"\nwarm restart: first query in {warm:.2f}s with "
      f"{proc2._prepare_calls} compile-pipeline runs "
      f"({st['disk_hits']} plan(s) loaded from disk; cold process "
      f"needed {cold_builds} builds)")
assert proc2._prepare_calls == 0
np.testing.assert_array_equal(
    r.values, out[tickets[0]].values)
print("warm values match the cold run exactly")

# 4. concurrent clients: GraphServer continuous batching ---------------------
# svc2 registered "roads" above, so its plans — and, via the access log
# persisted beside the plan cache, its HOT plans — are already warm.
server = api.GraphServer(
    service=svc2,
    wave=api.WavePolicy(
        max_wave=8,        # close a wave at 8 same-plan requests ...
        max_wait_s=0.05,   # ... or when the oldest has waited 50 ms
        max_pending=256))  # admission control: reject beyond this depth

futures = {}
lock = threading.Lock()


def client(thread_id, sources):
    """One 'user': submits requests and waits on its own futures."""
    for s in sources:
        fut = server.submit("roads",
                            api.QuerySpec(algo="sssp", sources=(s,)),
                            deadline=30.0)   # per-request budget (s)
        with lock:
            futures[(thread_id, s)] = fut


threads = [threading.Thread(target=client, args=(i, range(i, 16, 4)))
           for i in range(4)]
t0 = time.time()
for t in threads:
    t.start()
for t in threads:
    t.join()
results = {k: f.result(timeout=600) for k, f in futures.items()}
sched = server.stats()["scheduler"]
print(f"\nGraphServer: {len(results)} requests from 4 client threads in "
      f"{time.time() - t0:.2f}s over {sched['waves']} waves "
      f"(achieved wave size {sched['achieved_wave']:.1f})")
solo = svc2.run("roads", api.QuerySpec(algo="sssp", sources=(6,)))
np.testing.assert_array_equal(results[(2, 6)].values, solo.values)
print("wave-scheduled values are bit-identical to direct run() calls")

# deadlines + backpressure semantics in one breath: an impossible
# deadline resolves to DeadlineExceeded (never occupying a wave row),
# and a full queue / thrashing plan store raises Backpressure at submit
doomed = server.submit("roads", api.QuerySpec(algo="sssp", sources=(0,)),
                       deadline=0.0)
try:
    doomed.result(timeout=600)
except api.DeadlineExceeded as e:
    print(f"deadline semantics: {e}")
server.close()   # drains queued work, flushes the plan access log
sched = server.stats()["scheduler"]
print(f"server closed; scheduler stats: "
      f"{ {k: sched[k] for k in ('waves', 'expired', 'completed')} }")
