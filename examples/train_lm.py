"""End-to-end training driver example: a ~100M-parameter dense LM
(granite family scaled to d=768/L=12, GPT-2-small class) trained for a
few hundred steps on the synthetic corpus, with checkpointing and an
injected failure + automatic restart to demonstrate fault tolerance.

  PYTHONPATH=src python examples/train_lm.py            # full (~100M)
  PYTHONPATH=src python examples/train_lm.py --quick    # CI-sized
"""

import argparse
import dataclasses

from repro.configs import get_config
from repro.train.loop import TrainArgs, train_with_restarts


def model_100m():
    base = get_config("granite-3-2b")
    return dataclasses.replace(
        base, name="granite-100m", num_layers=12, d_model=768,
        num_heads=12, num_kv_heads=4, head_dim=64, d_ff=3072,
        vocab_size=32768, tie_embeddings=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    if args.quick:
        cfg = get_config("granite-3-2b").reduced()
        targs = TrainArgs(steps=60, batch_size=8, seq_len=64, lr=2e-3,
                          warmup=5, ckpt_dir=args.ckpt_dir,
                          ckpt_every=20, log_every=10, fail_at_step=35)
    else:
        cfg = model_100m()
        targs = TrainArgs(steps=args.steps, batch_size=args.batch,
                          seq_len=args.seq, lr=6e-4, warmup=30,
                          ckpt_dir=args.ckpt_dir, ckpt_every=50,
                          log_every=10, fail_at_step=args.steps // 2)
    n = cfg.param_count()
    print(f"model: {cfg.name}  ~{n/1e6:.0f}M params; injecting a failure "
          f"at step {targs.fail_at_step} (auto-restart from checkpoint)")
    out = train_with_restarts(cfg, targs)
    h = out["history"]
    print(f"\nrestarts: {out['restarts']}")
    print("loss curve:")
    for m in h:
        print(f"  step {m['step']:5d}  loss {m['loss']:.4f}  "
              f"ppl {m.get('ppl', float('nan')):.1f}")
    assert h[-1]["loss"] < h[0]["loss"]
    print("loss decreased through a failure+restart — fault-tolerant "
          "training works.")


if __name__ == "__main__":
    main()
