"""Quickstart: the asynchronous graph processor on a road network.

Session flow (paper Fig. 4 split): construct a ``GraphProcessor`` once —
profile → cluster → analyze → place happen lazily, once per plan — then
issue many queries against the cached device-resident image, compare the
paper's two models of computation, and print the modeled NALE/CPU/GPU
numbers (Fig. 5/6, scaled down).

  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro import api
from repro.core import compile as GC
from repro.core import graph as G
from repro.core import oracles as O

# 1. workload: a road network (sparse, high diameter — the hard case)
g = G.make_paper_graph("ca", scale=1 / 512, seed=0)
print(f"graph: {g.n} vertices, {g.nnz} edges, avg degree "
      f"{g.avg_degree:.2f}")

# 2. one session, many queries: the compile-time pipeline runs once per
#    plan and is shared by every query that can use it
proc = api.GraphProcessor(g, b=16, num_clusters=64)
res_async = proc.sssp(0)   # default policy: the paper's async engine
res_sync = proc.sssp(0, policy=api.ExecutionPolicy(mode="sync",
                                                   max_sweeps=100_000))
assert np.allclose(res_async.values, O.sssp_oracle(g, 0), rtol=1e-5,
                   atol=1e-4)
print(f"\nSSSP  async: {res_async.stats.sweeps} sweeps, "
      f"{res_async.stats.edge_work:.0f} edge relaxations")
print(f"SSSP  sync : {res_sync.stats.sweeps} sweeps, "
      f"{res_sync.stats.edge_work:.0f} edge relaxations")
print(f"→ self-timed execution does "
      f"{res_sync.stats.edge_work / res_async.stats.edge_work:.2f}x "
      f"less work than the global-clock baseline")

# 3. batched multi-source queries: one vmap'd run, one cached plan
multi = proc.sssp(sources=[0, g.n // 2, g.n - 1])
print(f"\nbatched SSSP from 3 sources: values {multi.values.shape}, "
      f"{multi.stats.sweeps} sweeps (straggler), one compile")
print(f"plan cache: {proc.cache_info()['plans']} plans for "
      f"{proc.cache_info()['prepare_calls']} prepare calls")

# 4. the compilation pipeline (Fig. 4): clustering → placement → ISA
p = res_async.prepared
c = p.clustering
print(f"\nclustering: {c.num_clusters} clusters, cut fraction "
      f"{c.cut_fraction:.3f}, balance {c.balance():.2f}")
prog = GC.compile_graph_program(p, "relax")
print(f"compiled {prog.total_instructions()} ISA instructions; "
      f"cluster 1 program head:")
print(prog.programs[1].disassemble(limit=6))

# 5. modeled platforms (constants in core/power.py) via the Result bundle
models = res_async.platform_models(sync_stats=res_sync.stats)
nale, cpu, gpu = models["nale"], models["cpu"], models["gpu"]
print(f"\nmodeled cycles: NALE {nale.cycles:.3g}  CPU {cpu.cycles:.3g} "
      f"({cpu.time_s / nale.time_s:.1f}x)  GPU {gpu.cycles:.3g}")
print(f"modeled power : NALE {nale.power_w:.2f} W  CPU {cpu.power_w:.2f} "
      f"W  GPU {gpu.power_w:.2f} W")
print(f"perf/W vs GPU : "
      f"{nale.perf_per_watt / gpu.perf_per_watt:.1f}x")

# 6. PageRank on the same session — a different semiring plan, same
#    clustering work pattern, zero graph re-upload between repeat queries
pr = proc.pagerank()
pr2 = proc.pagerank()
assert pr2.prepared is pr.prepared  # cache hit: no re-clustering
print(f"\nPageRank async: {pr.stats.sweeps} sweeps; top vertex "
      f"{int(np.argmax(pr.values))} (mass {pr.values.max():.2e}); "
      f"Σ={pr.values.sum():.6f}")
print(f"session now holds {proc.cache_info()['plans']} cached plans")
