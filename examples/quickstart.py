"""Quickstart: the asynchronous graph processor on a road network.

Runs the paper's full pipeline on a CA-road-like graph: profile →
cluster → compile-to-ISA → execute on the async engine, then compares
against the bulk-synchronous baseline and prints the modeled NALE/CPU/GPU
numbers (Fig. 5/6 of the paper, scaled down).

  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import algorithms as A
from repro.core import compile as GC
from repro.core import graph as G
from repro.core import oracles as O
from repro.core import power as PW

# 1. workload: a road network (sparse, high diameter — the hard case)
g = G.make_paper_graph("ca", scale=1 / 512, seed=0)
print(f"graph: {g.n} vertices, {g.nnz} edges, avg degree "
      f"{g.avg_degree:.2f}")

# 2. the paper's two models of computation
res_async = A.sssp(g, src=0, mode="async", b=16, num_clusters=64)
res_sync = A.sssp(g, src=0, mode="sync", b=16, num_clusters=64)
assert np.allclose(res_async.values, O.sssp_oracle(g, 0), rtol=1e-5,
                   atol=1e-4)
print(f"\nSSSP  async: {res_async.stats.sweeps} sweeps, "
      f"{res_async.stats.edge_work:.0f} edge relaxations")
print(f"SSSP  sync : {res_sync.stats.sweeps} sweeps, "
      f"{res_sync.stats.edge_work:.0f} edge relaxations")
print(f"→ self-timed execution does "
      f"{res_sync.stats.edge_work / res_async.stats.edge_work:.2f}x "
      f"less work than the global-clock baseline")

# 3. the compilation pipeline (Fig. 4): clustering → placement → ISA
p = res_async.prepared
c = p.clustering
print(f"\nclustering: {c.num_clusters} clusters, cut fraction "
      f"{c.cut_fraction:.3f}, balance {c.balance():.2f}")
prog = GC.compile_graph_program(p, "relax")
print(f"compiled {prog.total_instructions()} ISA instructions; "
      f"cluster 1 program head:")
print(prog.programs[1].disassemble(limit=6))

# 4. modeled platforms (constants in core/power.py)
nale = PW.model_nale(p, res_async.stats)
cpu = PW.model_cpu(p, res_async.stats)
gpu = PW.model_gpu(p, res_sync.stats,
                   k_max_pad=float(np.diff(g.indptr).max()),
                   avg_degree=g.avg_degree)
print(f"\nmodeled cycles: NALE {nale.cycles:.3g}  CPU {cpu.cycles:.3g} "
      f"({cpu.time_s / nale.time_s:.1f}x)  GPU {gpu.cycles:.3g}")
print(f"modeled power : NALE {nale.power_w:.2f} W  CPU {cpu.power_w:.2f} "
      f"W  GPU {gpu.power_w:.2f} W")
print(f"perf/W vs GPU : "
      f"{nale.perf_per_watt / gpu.perf_per_watt:.1f}x")

# 5. PageRank on the same clustered image
pr = A.pagerank(g, mode="async", tol=1e-8)
print(f"\nPageRank async: {pr.stats.sweeps} sweeps; top vertex "
      f"{int(np.argmax(pr.values))} (mass {pr.values.max():.2e}); "
      f"Σ={pr.values.sum():.6f}")
