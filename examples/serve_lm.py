"""Serving example: continuous batching with slot reuse on a reduced
config — 12 requests through 4 decode slots, verified against the static
batch path.

  PYTHONPATH=src python examples/serve_lm.py
"""

import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import lm
from repro.serve.engine import Request, ServeLoop, generate

cfg = get_config("granite-3-2b").reduced()
params, _ = lm.init(cfg, jax.random.PRNGKey(0))
rng = np.random.default_rng(0)

prompts = rng.integers(2, cfg.vocab_size, (12, 12)).astype(np.int32)

# static batch reference for the first 4
t0 = time.time()
static = generate(cfg, params, prompts[:4], max_new_tokens=8)
print(f"static batch of 4: {time.time()-t0:.1f}s")

sl = ServeLoop(cfg, params, num_slots=4, cache_len=40)
reqs = [Request(rid=i, prompt=prompts[i], max_new=8) for i in range(12)]
for r in reqs:
    sl.submit(r)
t0 = time.time()
steps = sl.run()
dt = time.time() - t0
tput = sum(len(r.generated) for r in reqs) / dt
print(f"continuous batching: 12 requests / 4 slots in {steps} decode "
      f"steps, {tput:.1f} tok/s")
for i in range(4):
    assert reqs[i].generated == static[i, 12:].tolist(), i
print("slot outputs match the static path — KV-cache slot surgery is "
      "exact.")
