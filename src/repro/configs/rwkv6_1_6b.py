"""RWKV-6 "Finch" 1.6B — attention-free, data-dependent decay linear RNN.
[arXiv:2404.05892; unverified]"""

from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    num_layers=24,
    d_model=2048,
    num_heads=32,            # 2048 / head_size 64
    num_kv_heads=0,
    d_ff=7168,
    vocab_size=65536,
    block_pattern=("rwkv",),
    attn_kind="none",
    pos_embedding="none",
    rwkv_head_size=64,
    ddlerp_rank=32,
    decay_rank=64,
    mlp_kind="squared_relu",  # rwkv channel-mix uses relu^2
    supports_long_context=True,   # O(1) state — run long_500k
))
