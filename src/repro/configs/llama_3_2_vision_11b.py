"""Llama-3.2-Vision-11B — dense GQA backbone with cross-attention image
layers every 5th layer; vision frontend is a STUB (input_specs provides
precomputed patch embeddings per the assignment).
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]"""

from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    block_pattern=("attn", "attn", "attn", "attn", "cross_attn"),
    mlp_kind="swiglu",
    rope_theta=500_000.0,
    img_seq=1601,            # 1 tile × (40×40 patches + 1 cls), stubbed
))
