"""Llama-4 Maverick 400B-A17B — 128-expert top-1 MoE with shared expert,
alternating dense/MoE layers (early-fusion backbone).
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""

from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    block_pattern=("attn", "moe"),   # alternating dense / MoE
    num_experts=128,
    top_k=1,
    shared_expert=True,
    mlp_kind="swiglu",
    rope_theta=500_000.0,
    optimizer="adafactor",
))
