"""MiniCPM3-4B — Multi-head Latent Attention (MLA): low-rank compressed
KV cache with decoupled RoPE keys.  [hf:openbmb/MiniCPM3-4B; hf]"""

from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="minicpm3-4b",
    family="dense",
    num_layers=62,
    d_model=2560,
    num_heads=40,
    num_kv_heads=40,       # MLA is effectively MHA over latent KV
    d_ff=6400,
    vocab_size=73448,
    block_pattern=("attn",),
    attn_kind="mla",
    q_lora_rank=768,
    kv_lora_rank=256,
    qk_nope_dim=64,
    qk_rope_dim=32,
    v_head_dim=64,
    head_dim=96,           # qk_nope + qk_rope
    mlp_kind="swiglu",
    rope_theta=10_000.0,
))
