"""DBRX-132B — fine-grained MoE, 16 experts top-4.
[hf:databricks/dbrx-base; unverified]"""

from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="dbrx-132b",
    family="moe",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=10752,
    vocab_size=100352,
    block_pattern=("moe",),          # every layer MoE (fine-grained)
    num_experts=16,
    top_k=4,
    mlp_kind="swiglu",
    rope_theta=500_000.0,
    optimizer="adafactor",           # 132B: factored stats to fit HBM
))
