"""RecurrentGemma-9B (Griffin) — RG-LRU recurrent blocks + local MQA
attention in a 2:1 pattern.  [arXiv:2402.19427; unverified]"""

from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,           # 12×(rec,rec,attn) + (rec,rec)
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,          # MQA
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    block_pattern=("recurrent", "recurrent", "local_attn"),
    window=2048,
    lru_dim=4096,
    conv_width=4,
    mlp_kind="swiglu",
    rope_theta=10_000.0,
    tie_embeddings=True,
    supports_long_context=True,   # O(window) cache — run long_500k
    optimizer="adamw",
))
