"""ChatGLM3-6B — dense, 2-D (partial) RoPE over half the head dims, GQA
kv=2.  [arXiv:2406.12793; hf]"""

from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="chatglm3-6b",
    family="dense",
    num_layers=28,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    head_dim=128,
    d_ff=13696,
    vocab_size=65024,
    block_pattern=("attn",),
    mlp_kind="swiglu",
    rope_fraction=0.5,     # 2d rope: rotary on half the head dimension
    rope_theta=10_000.0,
))
