"""Model configuration system + architecture registry.

One config file per assigned architecture lives beside this module; each
calls ``register()``.  ``reduced()`` derives the smoke-test config (same
family / block pattern, tiny dims) used by CPU tests; the full config is
exercised only through the dry-run (ShapeDtypeStruct, no allocation).
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | vlm | audio | hybrid
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 → d_model // num_heads

    # block structure: the repeating superblock of layer kinds; layers =
    # repeats * len(pattern) + remainder taken from the pattern prefix
    block_pattern: Tuple[str, ...] = ("attn",)   # attn|moe|rwkv|recurrent|local_attn|cross_attn

    # attention
    attn_kind: str = "gqa"          # gqa | mla | none
    rope_theta: float = 10_000.0
    rope_fraction: float = 1.0      # chatglm-style partial rotary
    window: Optional[int] = None    # local attention span
    pos_embedding: str = "rope"     # rope | learned | none

    # mlp
    mlp_kind: str = "swiglu"        # swiglu | squared_relu | gelu

    # moe
    num_experts: int = 0
    top_k: int = 0
    shared_expert: bool = False
    capacity_factor: float = 1.25
    moe_group_size: int = 1024      # dispatch group (memory/locality knob)
    router_aux_coef: float = 0.01
    router_z_coef: float = 1e-3

    # mla (minicpm3 / deepseek-style latent attention)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # rwkv
    rwkv_head_size: int = 64
    ddlerp_rank: int = 32
    decay_rank: int = 64

    # griffin / recurrentgemma
    lru_dim: int = 0                # 0 → d_model
    conv_width: int = 4

    # vlm / audio frontends (stubs per assignment: precomputed embeddings)
    img_seq: int = 0                # image-token count fed to cross-attn
    encoder_layers: int = 0
    encoder_seq: int = 0
    encdec: bool = False

    # misc
    norm: str = "rmsnorm"           # rmsnorm | layernorm
    tie_embeddings: bool = False
    max_seq: int = 8192

    # training defaults
    optimizer: str = "adamw"        # adamw | adafactor (≥100B configs)
    remat: bool = True
    # shard the residual stream's SEQ dim over the model axis at scan
    # boundaries (Megatron-style sequence parallelism for the saved
    # activations).  NOTE: measured counterproductive under GSPMD — seq-
    # sharded token contractions turn weight grads into full-shape
    # partials + all-reduce (EXPERIMENTS.md §Perf) — prefer remat_group.
    shard_seq_boundary: bool = False
    # checkpoint every `remat_group` superblocks instead of every one:
    # saved boundary activations shrink ÷G for one extra recompute of the
    # same work (total recompute unchanged), the standard deep-stack trade
    remat_group: int = 1
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"

    # which shape cells apply (assignment: long_500k only for sub-quadratic)
    supports_long_context: bool = False
    decoder: bool = True            # encoder-only archs would be False

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads:
            object.__setattr__(self, "head_dim",
                               self.d_model // self.num_heads)
        if self.lru_dim == 0 and "recurrent" in self.block_pattern:
            object.__setattr__(self, "lru_dim", self.d_model)

    # --- block layout ----------------------------------------------------
    @property
    def pattern_repeats(self) -> int:
        return self.num_layers // len(self.block_pattern)

    @property
    def remainder_layers(self) -> Tuple[str, ...]:
        rem = self.num_layers % len(self.block_pattern)
        return self.block_pattern[:rem]

    # --- bookkeeping -----------------------------------------------------
    def param_count(self) -> int:
        """Analytic parameter estimate (embeddings + blocks)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        total = v * d * (1 if self.tie_embeddings else 2)
        kinds = list(self.block_pattern) * self.pattern_repeats \
            + list(self.remainder_layers)
        hd = self.head_dim

        def attn_params():
            if self.attn_kind == "mla":
                qk = self.qk_nope_dim + self.qk_rope_dim
                return (d * self.q_lora_rank
                        + self.q_lora_rank * self.num_heads * qk
                        + d * (self.kv_lora_rank + self.qk_rope_dim)
                        + self.kv_lora_rank * self.num_heads * (
                            self.qk_nope_dim + self.v_head_dim)
                        + self.num_heads * self.v_head_dim * d)
            return d * hd * (self.num_heads + 2 * self.num_kv_heads) \
                + self.num_heads * hd * d

        for kind in kinds:
            if kind in ("attn", "local_attn", "cross_attn"):
                total += attn_params() + 2 * d + self._mlp_params(False)
            elif kind == "decoder":   # self-attn + cross-attn + mlp
                total += 2 * attn_params() + 3 * d \
                    + self._mlp_params(False)
            elif kind == "moe":
                total += attn_params() + 2 * d + self._mlp_params(True)
            elif kind == "rwkv":
                total += 4 * d * d + d * ff + ff * d + 2 * d \
                    + 5 * d * self.ddlerp_rank + 2 * d * self.decay_rank \
                    + d * d  # cr gate
            elif kind == "recurrent":
                total += 2 * d * self.lru_dim \
                    + 2 * self.lru_dim * self.lru_dim \
                    + self.lru_dim * d \
                    + 3 * self.lru_dim + self.conv_width * self.lru_dim \
                    + self._mlp_params(False) + 2 * d
        if self.pos_embedding == "learned":
            total += self.max_seq * d
        if self.img_seq:
            total += d * d  # frontend-stub projection
        if self.encdec:
            # encoder layers: self-attn + mlp (+ learned positions)
            total += self.encoder_layers * (
                4 * d * hd * self.num_heads
                + (3 if self.mlp_kind == "swiglu" else 2) * d * ff + 4 * d)
            if self.pos_embedding == "learned":
                total += self.encoder_seq * d
        return int(total)

    def _mlp_params(self, moe: bool) -> int:
        d, ff = self.d_model, self.d_ff
        per = (3 if self.mlp_kind == "swiglu" else 2) * d * ff
        if not moe:
            return per
        total = self.num_experts * per + d * self.num_experts
        if self.shared_expert:
            total += per
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k of num_experts)."""
        if not any(k == "moe" for k in self.block_pattern):
            return self.param_count()
        full = self.param_count()
        kinds = list(self.block_pattern) * self.pattern_repeats \
            + list(self.remainder_layers)
        n_moe = sum(1 for k in kinds if k == "moe")
        per = (3 if self.mlp_kind == "swiglu" else 2) * self.d_model * self.d_ff
        inactive = n_moe * (self.num_experts - self.top_k) * per
        return int(full - inactive)

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        pat = len(self.block_pattern)
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            num_layers=max(pat, min(2 * pat, self.num_layers)),
            d_model=64, num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads else 0,
            head_dim=16, d_ff=128, vocab_size=512,
            num_experts=min(self.num_experts, 4) if self.num_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            moe_group_size=64,
            q_lora_rank=16 if self.q_lora_rank else 0,
            kv_lora_rank=16 if self.kv_lora_rank else 0,
            qk_nope_dim=8 if self.qk_nope_dim else 0,
            qk_rope_dim=8 if self.qk_rope_dim else 0,
            v_head_dim=16 if self.v_head_dim else 0,
            rwkv_head_size=16, ddlerp_rank=8, decay_rank=8,
            lru_dim=64 if self.lru_dim else 0,
            window=min(self.window, 32) if self.window else None,
            img_seq=16 if self.img_seq else 0,
            encoder_layers=min(self.encoder_layers, 2),
            encoder_seq=24 if self.encoder_seq else 0,
            max_seq=128,
        )


_REGISTRY: Dict[str, ModelConfig] = {}

ARCH_IDS = [
    "dbrx-132b", "llama4-maverick-400b-a17b", "granite-3-2b",
    "chatglm3-6b", "minicpm3-4b", "nemotron-4-340b", "rwkv6-1.6b",
    "llama-3.2-vision-11b", "whisper-tiny", "recurrentgemma-9b",
]

_MODULE_OF = {
    "dbrx-132b": "dbrx_132b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "granite-3-2b": "granite_3_2b",
    "chatglm3-6b": "chatglm3_6b",
    "minicpm3-4b": "minicpm3_4b",
    "nemotron-4-340b": "nemotron_4_340b",
    "rwkv6-1.6b": "rwkv6_1_6b",
    "llama-3.2-vision-11b": "llama_3_2_vision_11b",
    "whisper-tiny": "whisper_tiny",
    "recurrentgemma-9b": "recurrentgemma_9b",
}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY and name in _MODULE_OF:
        importlib.import_module(f"repro.configs.{_MODULE_OF[name]}")
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_IDS}")
    return _REGISTRY[name]


def all_configs() -> Dict[str, ModelConfig]:
    for a in ARCH_IDS:
        get_config(a)
    return dict(_REGISTRY)
