from .base import (ARCH_IDS, ModelConfig, all_configs, get_config,
                   register)  # noqa: F401
