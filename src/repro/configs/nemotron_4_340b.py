"""Nemotron-4-340B — dense GQA with squared-ReLU MLP.
[arXiv:2402.16819; unverified]"""

from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="nemotron-4-340b",
    family="dense",
    num_layers=96,
    d_model=18432,
    num_heads=96,
    num_kv_heads=8,
    head_dim=192,
    d_ff=73728,
    vocab_size=256000,
    block_pattern=("attn",),
    mlp_kind="squared_relu",
    rope_theta=10_000.0,
    optimizer="adafactor",   # 340B: Adam moments would not fit 16 GB/chip
    remat_group=8,           # saved layer inputs: 14.5 GB → 1.8 GB/chip
))
