"""Whisper-tiny — encoder-decoder; conv/mel frontend is a STUB
(input_specs provides precomputed frame embeddings per the assignment).
[arXiv:2212.04356; unverified]"""

from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-tiny",
    family="audio",
    num_layers=4,            # decoder layers
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_size=51865,
    block_pattern=("decoder",),   # self-attn + cross-attn + mlp
    encdec=True,
    encoder_layers=4,
    encoder_seq=1500,        # 30 s of audio at 50 Hz after conv stride
    mlp_kind="gelu",
    norm="layernorm",
    pos_embedding="learned",
    tie_embeddings=True,
    max_seq=32768,            # learned-pos table must cover the 32k cells
))
