from .checkpoint import (latest_step, restore, save,
                         wait_for_async_saves)  # noqa: F401
