"""Fault-tolerant checkpointing (pure numpy; no orbax dependency).

Properties needed at 1000-node scale, implemented here at single-host
scale with the same interfaces:

  * **atomic**: write to ``step_XXXX.tmp`` then ``os.rename`` — a crash
    mid-save never corrupts the latest checkpoint;
  * **async**: disk I/O on a background thread after a synchronous
    device_get, so the train loop resumes immediately;
  * **elastic restore**: arrays are stored unsharded (per-host shards on a
    real pod); ``restore`` re-shards onto whatever mesh the new job has via
    device_put with the target shardings — restart on a different topology
    works (the elasticity boundary is the checkpoint, DESIGN.md §8);
  * **retention**: keeps the newest ``keep`` checkpoints.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional

import jax
import numpy as np

_PENDING: List[threading.Thread] = []


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten(template, flat: Dict[str, np.ndarray]):
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing {key}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(np.shape(leaf)):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs "
                f"template {np.shape(leaf)}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save(directory: str, step: int, params, opt_state=None,
         meta: Optional[Dict[str, Any]] = None, keep: int = 3,
         async_save: bool = False) -> str:
    """Write checkpoint for ``step``.  Returns final path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    # synchronous device→host transfer (cheap vs disk), async disk write
    payload = {"params": _flatten(params)}
    if opt_state is not None:
        payload["opt"] = _flatten(opt_state)
    meta = dict(meta or {}, step=step)

    def write():
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        for name, flat in payload.items():
            np.savez(os.path.join(tmp, name + ".npz"), **flat)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        _gc(directory, keep)

    if async_save:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        _PENDING.append(t)
    else:
        write()
    return final


def wait_for_async_saves():
    while _PENDING:
        _PENDING.pop().join()


def _gc(directory: str, keep: int):
    steps = sorted(_list_steps(directory))
    for s in steps[:-keep] if keep else []:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"),
                      ignore_errors=True)


def _list_steps(directory: str):
    out = []
    if not os.path.isdir(directory):
        return out
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            try:
                out.append(int(name[5:]))
            except ValueError:
                pass
    return out


def latest_step(directory: str) -> Optional[int]:
    steps = _list_steps(directory)
    return max(steps) if steps else None


def restore(directory: str, params_template, opt_template=None,
            step: Optional[int] = None, shardings=None,
            opt_shardings=None):
    """Load checkpoint; re-shard onto ``shardings`` if given (elastic)."""
    step = latest_step(directory) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with np.load(os.path.join(path, "params.npz")) as z:
        params = _unflatten(params_template, dict(z))
    if shardings is not None:
        params = jax.device_put(params, shardings)
    opt_state = None
    if opt_template is not None:
        with np.load(os.path.join(path, "opt.npz")) as z:
            opt_state = _unflatten(opt_template, dict(z))
        if opt_shardings is not None:
            opt_state = jax.device_put(opt_state, opt_shardings)
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    return params, opt_state, meta
