from . import compress, loop, optimizer, step  # noqa: F401
