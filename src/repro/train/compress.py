"""Int8 gradient/delta compression with error feedback.

Used on the cross-pod synchronization path (local-SGD outer loop and the
optional compressed DP all-reduce): 4× less ICI/DCN traffic per sync.
Error feedback keeps the quantization noise from accumulating — the
residual of each round is added back before the next quantization, giving
unbiased long-run updates (Seide et al. / EF-SGD).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def quantize(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-tensor symmetric int8.  Returns (q, scale)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_tree(tree, error):
    """Quantize a pytree with error feedback.  Returns (q_tree, scales,
    new_error).  ``error`` is the residual pytree from the previous round
    (zeros initially)."""
    def one(x, e):
        corrected = x.astype(jnp.float32) + e
        q, s = quantize(corrected)
        deq = dequantize(q, s)
        return q, s, corrected - deq

    out = jax.tree.map(one, tree, error)
    is3 = lambda t: isinstance(t, tuple)  # noqa: E731
    q = jax.tree.map(lambda t: t[0], out, is_leaf=is3)
    s = jax.tree.map(lambda t: t[1], out, is_leaf=is3)
    err = jax.tree.map(lambda t: t[2], out, is_leaf=is3)
    return q, s, err


def decompress_tree(q, s):
    return jax.tree.map(dequantize, q, s)


def zeros_error(tree):
    return jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), tree)


def compressed_bytes(tree) -> int:
    return sum(x.size for x in jax.tree.leaves(tree)) + \
        8 * len(jax.tree.leaves(tree))
