"""train_step factory: mixed precision, remat (in the model), gradient
accumulation (microbatching), optimizer update — one jittable function.

Gradient accumulation scans over microbatches so the live activation set
is one microbatch; required to fit train_4k (1M tokens) at ≥100B scale
(DESIGN.md §8)."""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..models import lm
from ..models.layers import dtype_of as layers_dtype


def make_train_step(cfg: ModelConfig, optimizer, accum_steps: int = 1,
                    attn_impl: str = "ref",
                    grad_accum_dtype=jnp.bfloat16,
                    grad_shardings=None,
                    sb_param_shardings=None) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics).  batch arrays have a global batch dim divisible by
    accum_steps.

    Mixed precision, master-weights style: the differentiated tree is the
    params cast to compute_dtype, so every backward buffer — including the
    stacked per-layer grad carried through the backward layer-scan — is
    bf16, not f32 (at 340B that single carry is 5 GB/chip in f32).  The
    f32 master params are only touched by the optimizer update.  The
    accumulator also lives in ``grad_accum_dtype`` (bf16 default); each
    microbatch contributes grad/accum_steps, keeping magnitudes scaled."""

    cd = layers_dtype(cfg.compute_dtype)

    def loss(p_low, mb):
        return lm.loss_fn(cfg, p_low, mb, attn_impl=attn_impl,
                          sb_param_shardings=sb_param_shardings)

    grad_fn = jax.value_and_grad(loss, has_aux=True)

    def cast_low(params):
        return jax.tree.map(
            lambda p: p.astype(cd) if p.dtype == jnp.float32 else p,
            params)

    def train_step(params, opt_state, batch):
        p_low = cast_low(params)
        if accum_steps == 1:
            (l, metrics), grads = grad_fn(p_low, batch)
            if grad_shardings is not None:
                grads = jax.lax.with_sharding_constraint(grads,
                                                         grad_shardings)
        else:
            def reshape(x):
                b = x.shape[0]
                mb = b // accum_steps
                return x.reshape((accum_steps, mb) + x.shape[1:])

            mbs = jax.tree.map(reshape, batch)

            # Differentiate THROUGH the microbatch scan (instead of
            # accumulating per-microbatch grads): XLA's backward scan
            # carries UNREDUCED grad partials, so the data-parallel
            # reduction fires ONCE per step instead of once per
            # microbatch — the DDP no_sync() trick, measured 8×
            # collective reduction on dbrx train_4k (EXPERIMENTS §Perf).
            def total_loss(p_l):
                def mb_loss(carry, mb):
                    l, metr = lm.loss_fn(
                        cfg, p_l, mb, attn_impl=attn_impl,
                        sb_param_shardings=sb_param_shardings)
                    return carry + l, metr

                lsum, metrs = jax.lax.scan(
                    jax.checkpoint(
                        mb_loss,
                        policy=jax.checkpoint_policies.nothing_saveable),
                    jnp.float32(0.0), mbs)
                return lsum / accum_steps, metrs

            (l, metrs), grads = jax.value_and_grad(
                total_loss, has_aux=True)(p_low)
            if grad_shardings is not None:
                grads = jax.lax.with_sharding_constraint(grads,
                                                         grad_shardings)
            grads = jax.tree.map(
                lambda g: g.astype(grad_accum_dtype), grads)
            metrics = jax.tree.map(lambda m: m.mean(), metrs)

        new_params, new_state, opt_metrics = optimizer.update(
            grads, opt_state, params)
        metrics = dict(metrics, loss=l, **opt_metrics)
        return new_params, new_state, metrics

    return train_step


def jit_train_step(train_step, mesh=None, param_shardings=None,
                   state_shardings=None, batch_sharding=None,
                   donate: bool = True):
    kw: Dict[str, Any] = {}
    if mesh is not None:
        kw["in_shardings"] = (param_shardings, state_shardings,
                              batch_sharding)
        kw["out_shardings"] = (param_shardings, state_shardings, None)
    if donate:
        kw["donate_argnums"] = (0, 1)
    return jax.jit(train_step, **kw)


_ = (functools, Optional, Tuple)
