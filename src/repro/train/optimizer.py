"""Native optimizers (no external deps): AdamW and Adafactor, plus
global-norm clipping and warmup-cosine schedules.

Adafactor (factored second moment, no first moment by default) is the
default for ≥100B configs — Adam's m/v in f32 would not fit 16 GB/chip at
340B scale even fully sharded (see DESIGN.md §8).

State pytrees mirror the parameter pytree, so parameter sharding specs
apply directly (factored stats drop the factored axis).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..sharding.rules import parse_axes


# ---------------------------------------------------------------------------
# schedules / clipping
# ---------------------------------------------------------------------------


def warmup_cosine(base_lr: float, warmup: int, total: int,
                  final_frac: float = 0.1) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
        t = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, base_lr * cos)
    return lr


def clip_by_global_norm(grads, max_norm: float):
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gn


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip: float = 1.0

    def init(self, params):
        z = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return {"m": z, "v": jax.tree.map(jnp.zeros_like, z),
                "count": jnp.zeros((), jnp.int32)}

    def state_axes(self, param_axes):
        return {"m": param_axes, "v": param_axes, "count": ""}

    def update(self, grads, state, params):
        grads, gn = clip_by_global_norm(grads, self.clip)
        c = state["count"] + 1
        cf = c.astype(jnp.float32)
        bc1 = 1 - self.b1 ** cf
        bc2 = 1 - self.b2 ** cf
        lr = self.lr(c)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = self.b1 * m + (1 - self.b1) * g
            v = self.b2 * v + (1 - self.b2) * jnp.square(g)
            step = (m / bc1) / (jnp.sqrt(v / bc2) + self.eps)
            step = step + self.weight_decay * p.astype(jnp.float32)
            return m, v, (p.astype(jnp.float32) - lr * step).astype(p.dtype)

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        m = jax.tree.map(lambda t: t[0], out,
                         is_leaf=lambda t: isinstance(t, tuple))
        v = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
        new_p = jax.tree.map(lambda t: t[2], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        return new_p, {"m": m, "v": v, "count": c}, {"grad_norm": gn,
                                                     "lr": lr}


# ---------------------------------------------------------------------------
# Adafactor (Shazeer & Stern 2018), beta1=0 variant
# ---------------------------------------------------------------------------


def _factored(shape) -> bool:
    return len(shape) >= 2


# leaves bigger than this are updated slice-by-slice along axis 0 with
# lax.map — the f32 update chain on a stacked (96, 1152, 4608) leaf would
# otherwise hold multiple ~2 GB/chip transients at 340B scale
_CHUNK_UPDATE_ELEMS = 32 * 1024 * 1024


@dataclasses.dataclass(frozen=True)
class Adafactor:
    lr: Callable
    decay: float = 0.8          # \hat{beta2}_t = 1 - t^-decay
    eps: float = 1e-30
    clip_update: float = 1.0    # update RMS clip (d in the paper)
    weight_decay: float = 0.0
    clip: float = 1.0

    def init(self, params):
        def one(p):
            if _factored(p.shape):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros_like(p, jnp.float32)}
        return {"stats": jax.tree.map(one, params),
                "count": jnp.zeros((), jnp.int32)}

    def state_axes(self, param_axes):
        def one(ax):
            axes = parse_axes(ax)
            if len(axes) >= 2:
                def j(t):
                    return " ".join("." if a is None else a for a in t)
                return {"vr": j(axes[:-1]), "vc": j(axes[:-2] + axes[-1:])}
            return {"v": ax}
        return {"stats": jax.tree.map(one, param_axes), "count": ""}

    def update(self, grads, state, params):
        grads, gn = clip_by_global_norm(grads, self.clip)
        c = state["count"] + 1
        cf = c.astype(jnp.float32)
        beta2 = 1.0 - cf ** (-self.decay)
        lr = self.lr(c)

        def upd_one(g, s, p):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + self.eps
            if _factored(g.shape):
                vr = beta2 * s["vr"] + (1 - beta2) * g2.mean(axis=-1)
                vc = beta2 * s["vc"] + (1 - beta2) * g2.mean(axis=-2)
                denom = jnp.maximum(vr.mean(axis=-1, keepdims=True), 1e-30)
                vr_hat = vr / denom                     # (..., A)
                u = g * jax.lax.rsqrt(vr_hat)[..., None] \
                    * jax.lax.rsqrt(vc)[..., None, :]
                ns = {"vr": vr, "vc": vc}
            else:
                v = beta2 * s["v"] + (1 - beta2) * g2
                u = g * jax.lax.rsqrt(v)
                ns = {"v": v}
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-30)
            u = u / jnp.maximum(1.0, rms / self.clip_update)
            u = u + self.weight_decay * p.astype(jnp.float32)
            return ns, (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        # NOTE: an attempted lax.map-over-layer-slices here (to cap the f32
        # update-chain transients) backfired badly under GSPMD: the map
        # body re-decided shardings and inserted 2×10 GiB full all-gathers
        # of the stacked kv weights.  Hypothesis→refuted; recorded in
        # EXPERIMENTS.md §Perf.  Instead, LEAF UPDATES ARE SERIALIZED with
        # optimization_barrier: independent leaves would otherwise be
        # scheduled concurrently and their f32 update-chain transients
        # coexist (Σ leaves instead of max leaf — ~8 GB/chip at 340B).

        def is_stat(t):
            return isinstance(t, dict) and set(t) in ({"v"}, {"vr", "vc"})

        g_leaves, treedef = jax.tree_util.tree_flatten(grads)
        s_leaves = treedef.flatten_up_to(
            jax.tree.map(lambda s: s, state["stats"], is_leaf=is_stat))
        p_leaves = jax.tree_util.tree_leaves(params)
        # big leaves last, serialized among themselves
        order = sorted(range(len(g_leaves)),
                       key=lambda i: g_leaves[i].size)
        ns_list = [None] * len(g_leaves)
        np_list = [None] * len(g_leaves)
        token = None
        for i in order:
            g = g_leaves[i]
            if token is not None and g.size > 2 ** 20:
                # all barrier inputs must be ready before any output is:
                # leaf i's chain cannot start until leaf i-1 finished
                g, _ = jax.lax.optimization_barrier((g, token))
            ns, pn = upd_one(g, s_leaves[i], p_leaves[i])
            if pn.size > 2 ** 20:
                token = pn
            ns_list[i], np_list[i] = ns, pn
        stats = jax.tree_util.tree_unflatten(treedef, ns_list)
        new_p = jax.tree_util.tree_unflatten(treedef, np_list)
        return new_p, {"stats": stats, "count": c}, {"grad_norm": gn,
                                                     "lr": lr}


def make_optimizer(name: str, lr_fn: Callable, **kw):
    if name == "adamw":
        return AdamW(lr=lr_fn, **kw)
    if name == "adafactor":
        return Adafactor(lr=lr_fn, **kw)
    raise ValueError(name)


_ = (Any, Dict, Optional, Tuple)
