"""Training loop: checkpoint/restart fault tolerance, simulated failures,
and a straggler-tolerant local-SGD outer loop with compressed deltas.

Fault model (scaled down from 1000-node practice):
  * a step may raise ``SimulatedFailure`` (tests inject this) — the loop
    restarts from the last checkpoint, rebuilding the data iterator at the
    restored step: bitwise-deterministic recovery;
  * checkpoints are atomic + async (ckpt/checkpoint.py) and restore onto a
    different mesh (elastic);
  * in local-SGD mode, W workers take H local steps between syncs — a
    straggler only delays its own shard, and the sync payload is int8 with
    error feedback (train/compress.py), 4× less cross-pod traffic.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .. import ckpt
from ..configs.base import ModelConfig
from ..data.pipeline import SyntheticCorpus, make_iterator
from ..models import lm
from . import compress
from .optimizer import make_optimizer, warmup_cosine
from .step import make_train_step


class SimulatedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class TrainArgs:
    steps: int = 100
    batch_size: int = 8
    seq_len: int = 128
    lr: float = 3e-3
    warmup: int = 20
    accum_steps: int = 1
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    keep: int = 3
    log_every: int = 10
    seed: int = 0
    fail_at_step: Optional[int] = None    # simulate a node failure
    async_ckpt: bool = False


def _extras_for(cfg: ModelConfig, batch_size: int):
    ex = {}
    if cfg.img_seq:
        ex["img_embeds"] = lambda i: np.random.default_rng((i, 7)) \
            .standard_normal((batch_size, cfg.img_seq, cfg.d_model)) \
            .astype(np.float32)
    if cfg.encdec:
        ex["enc_embeds"] = lambda i: np.random.default_rng((i, 11)) \
            .standard_normal((batch_size, cfg.encoder_seq, cfg.d_model)) \
            .astype(np.float32)
    return ex


def train(cfg: ModelConfig, args: TrainArgs,
          hooks: Optional[Dict[str, Callable]] = None) -> Dict[str, Any]:
    """Single-replica training with checkpoint/restart.  Returns history.

    Failure semantics: if a SimulatedFailure fires (or any step raises),
    callers can simply call ``train`` again with the same ckpt_dir — it
    resumes from the latest checkpoint.
    """
    hooks = hooks or {}
    opt = make_optimizer(cfg.optimizer,
                         warmup_cosine(args.lr, args.warmup, args.steps))
    train_step = jax.jit(make_train_step(cfg, opt, args.accum_steps),
                         donate_argnums=(0, 1))

    params, _ = lm.init(cfg, jax.random.PRNGKey(args.seed))
    opt_state = opt.init(params)
    start = 0
    if args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) is not None:
        params, opt_state, meta = ckpt.restore(
            args.ckpt_dir, params, opt_state)
        start = int(meta["step"])

    corpus = SyntheticCorpus(cfg.vocab_size, seed=args.seed)
    it = make_iterator(corpus, args.batch_size, args.seq_len,
                       start_step=start,
                       extras=_extras_for(cfg, args.batch_size))

    history: List[Dict[str, float]] = []
    t0 = time.time()
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        if args.fail_at_step is not None and step == args.fail_at_step:
            raise SimulatedFailure(f"injected failure at step {step}")
        params, opt_state, metrics = train_step(params, opt_state, batch)
        if (step + 1) % args.log_every == 0 or step == args.steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = step + 1
            m["wall_s"] = time.time() - t0
            history.append(m)
            if "on_log" in hooks:
                hooks["on_log"](m)
        if args.ckpt_dir and ((step + 1) % args.ckpt_every == 0
                              or step == args.steps - 1):
            ckpt.save(args.ckpt_dir, step + 1, params, opt_state,
                      keep=args.keep, async_save=args.async_ckpt)
    ckpt.wait_for_async_saves()
    return {"params": params, "opt_state": opt_state, "history": history,
            "final_step": args.steps}


def train_with_restarts(cfg: ModelConfig, args: TrainArgs,
                        max_restarts: int = 3) -> Dict[str, Any]:
    """Run-until-done driver: restart from checkpoint on failure (the
    behaviour a cluster scheduler provides at datacenter scale)."""
    restarts = 0
    while True:
        try:
            out = train(cfg, args)
            out["restarts"] = restarts
            return out
        except SimulatedFailure:
            restarts += 1
            if restarts > max_restarts:
                raise
            args = dataclasses.replace(args, fail_at_step=None)


# ---------------------------------------------------------------------------
# local-SGD (async outer loop) — the paper's "local latencies, not global
# worst-case" applied at datacenter scale
# ---------------------------------------------------------------------------


def train_local_sgd(cfg: ModelConfig, args: TrainArgs, workers: int = 2,
                    sync_period: int = 10,
                    compress_deltas: bool = True) -> Dict[str, Any]:
    """W logical pods each run ``sync_period`` local steps, then exchange
    parameter *deltas* (int8 + error feedback when compress_deltas) and
    average.  Simulated sequentially on one host; on a real deployment each
    worker is a pod and the averaging is a DCN all-reduce."""
    opt = make_optimizer(cfg.optimizer,
                         warmup_cosine(args.lr, args.warmup, args.steps))
    train_step = jax.jit(make_train_step(cfg, opt, args.accum_steps))

    global_params, _ = lm.init(cfg, jax.random.PRNGKey(args.seed))
    opt_states = [opt.init(global_params) for _ in range(workers)]
    err = [compress.zeros_error(global_params) for _ in range(workers)]
    corpus = SyntheticCorpus(cfg.vocab_size, seed=args.seed)
    iters = [make_iterator(corpus, args.batch_size, args.seq_len,
                           shard=w, num_shards=workers,
                           extras=_extras_for(cfg, args.batch_size))
             for w in range(workers)]

    history = []
    comm_bytes = 0
    step = 0
    while step < args.steps:
        deltas = []
        losses = []
        for w in range(workers):
            p = global_params
            for h in range(sync_period):
                batch = {k: jnp.asarray(v) for k, v in next(iters[w]).items()}
                p, opt_states[w], metrics = train_step(p, opt_states[w],
                                                       batch)
            losses.append(float(metrics["loss"]))
            delta = jax.tree.map(lambda a, b: (a - b).astype(jnp.float32),
                                 p, global_params)
            if compress_deltas:
                q, s, err[w] = compress.compress_tree(delta, err[w])
                delta = compress.decompress_tree(q, s)
                comm_bytes += compress.compressed_bytes(q)
            else:
                comm_bytes += 4 * sum(x.size for x in jax.tree.leaves(delta))
            deltas.append(delta)
        mean_delta = jax.tree.map(
            lambda *ds: sum(ds) / len(ds), *deltas)
        global_params = jax.tree.map(
            lambda p_, d: (p_.astype(jnp.float32) + d).astype(p_.dtype),
            global_params, mean_delta)
        step += sync_period
        history.append({"step": step, "loss": float(np.mean(losses)),
                        "comm_bytes": comm_bytes})
    return {"params": global_params, "history": history,
            "comm_bytes": comm_bytes}
