"""Measured tiling autotuner for the Pallas SpMV kernels.

The paper's NALE array is self-timed — throughput follows the data, not
a static worst-case schedule.  The software analogue of picking FIFO
depths is picking the Pallas tiling knobs (``block_size`` bk,
``rows_per_step``), and the honest way to pick them is to *measure* a
small calibration sweep on the actual plan's tile structure, not to
trust a model: interpret mode (off-TPU), VMEM residency, and grid
overhead are all invisible to an analytical roofline.

``autotune_spmv(p, spec)`` sweeps the free knobs of ``spec`` over the
plan ``p`` (duck-typed: any object with ``vals/cols/nnz/valid/k_max/
r_pad/b/semiring`` — ``core.engine.Prepared`` qualifies, but this module
must not import ``repro.core``), timing one representative sweep per
candidate on a seeded ~25%-dense calibration frontier.  The winner is
deterministic for a given seed and measurement function: ties break
toward the smallest (block_size, rows_per_step).

Each tuning record carries a roofline cross-check from
``launch.roofline.kernel_roofline``: ``roofline_agrees`` is True when
the measured time is at or above the modeled lower bound (a measurement
*below* the roofline means the harness mis-timed — flagged, never used
to override the measurement).

The caller (``core/api.GraphProcessor``) caches the returned record in
the PlanStore keyed by ``(fingerprint, PlanKey(kernel=spec))`` so warm
restarts reuse tunings instead of re-measuring.
"""

from __future__ import annotations

import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..launch.roofline import kernel_roofline
from . import ops
from .bsr_spmv import _init_val
from .spec import KernelSpec

CALIBRATION_DENSITY = 0.25
BK_CANDIDATES = (2, 4, 8, 16)
RS_CANDIDATES = (1, 2, 4)


def default_measure(call: Callable[[], object], config: KernelSpec,
                    iters: int) -> float:
    """Wall-clock a candidate: one warm-up call (compile), then the best
    of ``iters`` synchronized runs.  Injectable for tests."""
    del config
    jax.block_until_ready(call())
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(call())
        best = min(best, time.perf_counter() - t0)
    return best


def candidate_specs(spec: KernelSpec, k_max: int):
    """Concrete candidate grid for ``spec``'s free knobs.  Pinned fields
    stay pinned; bk candidates never exceed the padded tile-chunk axis."""
    if spec.block_size is not None:
        bks = [spec.block_size]
    else:
        cap = max(int(k_max), 2)
        bks = [c for c in BK_CANDIDATES if c <= cap] or [2]
    if spec.fuse_frontier:
        rss = [1]
    elif spec.rows_per_step is not None:
        rss = [spec.rows_per_step]
    else:
        rss = list(RS_CANDIDATES)
    return [
        KernelSpec(impl=spec.impl, block_size=bk, rows_per_step=rs,
                   fuse_frontier=spec.fuse_frontier)
        for bk in bks for rs in rss
    ]


def _calibration_inputs(p, seed: int, apply_kind: str):
    """Seeded synthetic state on the plan's real tile structure."""
    rng = np.random.default_rng(seed)
    r_pad, b = int(p.r_pad), int(p.b)
    zero = _init_val(p.semiring)
    x = jnp.asarray(np.where(
        rng.random((r_pad, b)) < 0.5, rng.random((r_pad, b)), zero),
        jnp.float32)
    act = jnp.asarray(rng.random(r_pad) < CALIBRATION_DENSITY)
    damping = jnp.float32(0.85)
    tol = jnp.float32(1e-6)
    inv_n = jnp.float32(1.0 / max(int(getattr(p, "n", r_pad * b)), 1))
    return x, act, damping, tol, inv_n


def _modeled_seconds(p, act, fused: bool) -> dict:
    """Roofline lower bound for one calibration sweep: bytes follow the
    tiles actually walked (active rows for the fused kernel, all rows
    unfused) plus the resident x image; flops are semiring MACs."""
    b = int(p.b)
    nnz = np.asarray(p.nnz, dtype=np.float64)
    if fused:
        tiles = float(nnz[np.asarray(act)].sum())
    else:
        tiles = float(nnz.sum())
    tile_bytes = b * b * 4 + 4 + 4          # vals + col index + nnz amort
    hbm = tiles * tile_bytes + float(p.r_pad) * b * 4 * 3  # x in, x/y out
    flops = tiles * 2.0 * b * b
    return kernel_roofline(flops, hbm)


def autotune_spmv(p, spec: KernelSpec, seed: int = 0, iters: int = 3,
                  measure: Optional[Callable] = None,
                  apply_kind: str = "relax",
                  platform: Optional[str] = None) -> dict:
    """Measure ``spec``'s free tiling knobs on plan ``p``; return a
    JSON-serializable tuning record (see module docstring)."""
    if spec.impl != "pallas":
        raise ValueError(f"autotune targets the Pallas kernel, not "
                         f"impl={spec.impl!r}")
    measure = measure or default_measure
    x, act, damping, tol, inv_n = _calibration_inputs(p, seed, apply_kind)
    vals, cols, nnz, valid = p.vals, p.cols, p.nnz, p.valid

    results = []
    for cand in candidate_specs(spec, p.k_max):
        fn = ops.select_kernel("bsr_spmv", cand, platform=platform)
        if cand.fuse_frontier:
            def call(fn=fn):
                return fn(vals, cols, nnz, x, x, valid, act, damping,
                          tol, inv_n, semiring=p.semiring,
                          apply_kind=apply_kind)
        else:
            def call(fn=fn):
                return fn(vals, cols, nnz, x, semiring=p.semiring)
        t = float(measure(call, cand, iters))
        results.append((t, cand))

    t_best, best = min(
        results, key=lambda r: (r[0], r[1].block_size, r[1].rows_per_step))
    model = _modeled_seconds(p, act, spec.fuse_frontier)
    return {
        "block_size": int(best.block_size),
        "rows_per_step": int(best.rows_per_step),
        "measured_s": t_best,
        "modeled_s": model["modeled_s"],
        "roofline_agrees": bool(t_best >= model["modeled_s"]),
        "seed": int(seed),
        "candidates": [
            {"block_size": int(c.block_size),
             "rows_per_step": int(c.rows_per_step), "measured_s": t}
            for t, c in results
        ],
    }
