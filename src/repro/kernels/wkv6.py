"""WKV6 recurrence Pallas kernel — RWKV-6's data-dependent-decay state
update, the LM-side analogue of the paper's MAC-with-local-state NALE.

Per head, per step:   a_t   = k_tᵀ v_t              (outer product, MXU)
                      y_t   = r_t (S + u ⊙ a_t)     (readout)
                      S     = diag(w_t) S + a_t      (decayed state)

Grid: (batch·heads, time-chunks) with the chunk axis innermost; the
(hs, hs) state lives in VMEM scratch across chunk iterations (the NALE's
local FIFO store), so HBM traffic is the r/k/v/w streams only — the
XLA scan path re-reads state from HBM every step.

Layout: r,k,v,w as (BH, T, hs); u (hs,); y (BH, T, hs); final state out
(BH, hs, hs).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    _VMEM = None


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref,
                 sout_ref, state, *, chunk: int, nc: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _():
        state[...] = s0_ref[0]

    def step(t, _):
        r = r_ref[0, t, :].astype(jnp.float32)       # (hs,)
        k = k_ref[0, t, :].astype(jnp.float32)
        v = v_ref[0, t, :].astype(jnp.float32)
        w = w_ref[0, t, :].astype(jnp.float32)
        u = u_ref[...].astype(jnp.float32)
        a = k[:, None] * v[None, :]                  # (hs, hs) outer
        y = jnp.einsum("k,kv->v", r, state[...] + u[:, None] * a,
                       preferred_element_type=jnp.float32)
        y_ref[0, t, :] = y.astype(y_ref.dtype)
        state[...] = w[:, None] * state[...] + a
        return 0

    jax.lax.fori_loop(0, chunk, step, 0)

    @pl.when(ci == nc - 1)
    def _():
        sout_ref[0] = state[...].astype(sout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r, k, v, w, u, s0, chunk: int = 64, interpret: bool = True):
    """r,k,v,w: (BH, T, hs); u: (hs,); s0: (BH, hs, hs).
    Returns (y (BH, T, hs), s_final (BH, hs, hs))."""
    bh, t, hs = r.shape
    if t % chunk:
        chunk = t
    nc = t // chunk
    grid = (bh, nc)
    kern = functools.partial(_wkv6_kernel, chunk=chunk, nc=nc)
    y, sout = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, hs), lambda b, c: (b, c, 0)),  # r
            pl.BlockSpec((1, chunk, hs), lambda b, c: (b, c, 0)),  # k
            pl.BlockSpec((1, chunk, hs), lambda b, c: (b, c, 0)),  # v
            pl.BlockSpec((1, chunk, hs), lambda b, c: (b, c, 0)),  # w
            pl.BlockSpec((hs,), lambda b, c: (0,)),                # u
            pl.BlockSpec((1, hs, hs), lambda b, c: (b, 0, 0)),     # s0
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, hs), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, hs, hs), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, t, hs), r.dtype),
            jax.ShapeDtypeStruct((bh, hs, hs), jnp.float32),
        ],
        scratch_shapes=[_VMEM((hs, hs), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u, s0)
    return y, sout


def wkv6_ref(r, k, v, w, u, s0):
    """Oracle: plain scan (same math as models/rwkv._wkv_scan, flattened
    heads)."""
    def step(s, inp):
        r_t, k_t, v_t, w_t = inp
        a = k_t[:, :, None] * v_t[:, None, :]
        y = jnp.einsum("bk,bkv->bv", r_t, s + u[None, :, None] * a)
        s = w_t[:, :, None] * s + a
        return s, y

    xs = tuple(x.transpose(1, 0, 2).astype(jnp.float32)
               for x in (r, k, v, w))
    s, ys = jax.lax.scan(step, s0.astype(jnp.float32), xs)
    return ys.transpose(1, 0, 2).astype(r.dtype), s
