"""KernelSpec — the structured kernel-selection half of an ExecutionPolicy.

The bare ``impl="ref"|"pallas"`` string that used to live on
``ExecutionPolicy`` said *which* kernel but nothing about *how* to run
it.  ``KernelSpec`` is that surface made explicit:

  impl           "ref" (pure-jnp oracle, XLA-fused; SPMD-partitionable)
                 or "pallas" (Mosaic kernel; interpret mode off-TPU).
  block_size     bk — tiles staged HBM→VMEM per grid step of the Pallas
                 SpMV (the inner tile-chunk width).  None = default (or
                 the autotuned winner when ``autotune=True``).
  rows_per_step  row-blocks relaxed per grid step of the *unfused*
                 Pallas SpMV (coarsens the grid; trades launch overhead
                 against VMEM residency).  The fused kernel walks its
                 compact active-row list one row-block per step, so it
                 only accepts None/1 here.
  fuse_frontier  run the fused relax + frontier-select + convergence-
                 reduce kernel with active-tile skipping (see
                 ``bsr_spmv.bsr_spmv_fused``) instead of SpMV + separate
                 XLA apply/reduce ops.
  autotune       measure (not model) the free tiling knobs on a small
                 calibration run at prepare() time and cache the winner
                 beside the plan in the PlanStore.

Incoherent combinations fail loudly at construction (mirroring the
PR-7 ``dist_flavor`` validation on ``ExecutionPolicy``): every knob
other than ``impl`` describes the Pallas kernel, so they all require
``impl="pallas"``; ``autotune`` with every tunable pinned has nothing
left to tune.

Specs are frozen/hashable: they ride in ``ExecutionPolicy`` equality
(wave coalescing) and in ``PlanKey`` (tuning cache identity).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

IMPLS = ("ref", "pallas")

DEFAULT_BLOCK_SIZE = 8     # bk: tile-chunk width of the Pallas SpMV grid
DEFAULT_ROWS_PER_STEP = 1


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    impl: str = "ref"
    block_size: Optional[int] = None
    rows_per_step: Optional[int] = None
    fuse_frontier: bool = False
    autotune: bool = False

    def __post_init__(self):
        if self.impl not in IMPLS:
            raise ValueError(
                f"impl must be one of {IMPLS}: {self.impl!r}")
        for field in ("block_size", "rows_per_step"):
            v = getattr(self, field)
            if v is not None and (not isinstance(v, int) or v < 1):
                raise ValueError(
                    f"{field} must be None or a positive int: {v!r}")
        if self.impl == "ref":
            bad = [f for f in ("block_size", "rows_per_step") if
                   getattr(self, f) is not None]
            bad += [f for f in ("fuse_frontier", "autotune") if
                    getattr(self, f)]
            if bad:
                raise ValueError(
                    f"{'/'.join(bad)} describe the Pallas kernel and "
                    "require impl='pallas'; the ref path has no tiling "
                    "knobs")
        if self.fuse_frontier and self.rows_per_step not in (None, 1):
            raise ValueError(
                "the fused kernel walks its compact active-row list one "
                "row-block per grid step; rows_per_step="
                f"{self.rows_per_step} needs fuse_frontier=False")
        if self.autotune:
            tunables = ("block_size",) if self.fuse_frontier else \
                ("block_size", "rows_per_step")
            if all(getattr(self, f) is not None for f in tunables):
                raise ValueError(
                    "autotune=True with every tunable pinned "
                    f"({', '.join(tunables)}) has nothing to tune; "
                    "unpin one or drop autotune")

    def concrete(self, tuning: Optional[dict] = None) -> "KernelSpec":
        """The spec engines actually execute: free knobs filled from a
        tuning record (``kernels.autotune`` output) or defaults, and the
        ``autotune`` request flag stripped (it described *how to pick*
        the knobs, not the kernel itself)."""
        t = tuning or {}
        if self.impl == "ref":
            return KernelSpec(impl="ref")
        bk = self.block_size or int(t.get("block_size")
                                    or DEFAULT_BLOCK_SIZE)
        if self.fuse_frontier:
            rs = 1
        else:
            rs = self.rows_per_step or int(t.get("rows_per_step")
                                           or DEFAULT_ROWS_PER_STEP)
        return KernelSpec(impl=self.impl, block_size=bk, rows_per_step=rs,
                          fuse_frontier=self.fuse_frontier, autotune=False)


def as_kernel_spec(spec) -> KernelSpec:
    """Coerce the historical spellings — None (defaults) and the bare
    impl string — into a KernelSpec."""
    if spec is None:
        return KernelSpec()
    if isinstance(spec, str):
        return KernelSpec(impl=spec)
    if isinstance(spec, KernelSpec):
        return spec
    raise TypeError(
        f"expected KernelSpec, impl string or None, got {type(spec)}")
