"""Pure-jnp oracles for every Pallas kernel (the ground truth for tests).

These are also the production fallback path on backends without Mosaic
(this CPU container, GPU): ``ops.py`` dispatches kernel vs. reference.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# bsr_spmv — block-sparse semiring SpMV
# ---------------------------------------------------------------------------


def bsr_spmv_ref(block_vals: jnp.ndarray, block_cols: jnp.ndarray,
                 x: jnp.ndarray, semiring: str = "plus_times") -> jnp.ndarray:
    """y[r*b+i] = ⊕_{k,j} vals[r,k,i,j] ⊗ x[cols[r,k]*b+j].

    Args:
      block_vals: (R, K, B, B) tile values (padded with ⊕-identity).
      block_cols: (R, K) int32 col-block ids (padding points anywhere; the
        padded tile's values are ⊕-identities so the result is unaffected).
      x: (C, B) input vector in block layout.
      semiring: any registered semiring name.  The four built-ins get
        hand-fused einsum/min/max paths; anything else falls back to the
        ring's own mul + generic ⊕-reduce (correct for every semiring
        whose ⊕-identity absorbs under ⊗ — the ``semiring.register``
        contract).
    Returns:
      y: (R, B).
    """
    xs = x[block_cols]  # (R, K, B)
    if semiring == "plus_times":
        return jnp.einsum("rkij,rkj->ri", block_vals, xs)
    if semiring == "min_plus":
        t = block_vals + xs[:, :, None, :]          # (R, K, B, B)
        return jnp.min(t, axis=(1, 3))
    if semiring == "max_min":
        t = jnp.minimum(block_vals, xs[:, :, None, :])
        return jnp.max(t, axis=(1, 3))
    if semiring == "min_select":
        # mul(w, x) = x when an edge exists; absent edges hold +inf weight.
        t = jnp.where(jnp.isfinite(block_vals), xs[:, :, None, :], jnp.inf)
        return jnp.min(t, axis=(1, 3))
    # registered custom semiring: generic ⊗-then-⊕ over the tile and
    # source axes.  Imported lazily — this runs post-import (kernels/
    # must not import core/ at module load; core.__init__ → engine →
    # kernels.ops would cycle).
    from ..core import semiring as _sr
    ring = _sr.get(semiring)
    t = ring.mul(block_vals, xs[:, :, None, :])     # (R, K, B, B)
    return ring.reduce(t, axis=(1, 3))


# ---------------------------------------------------------------------------
# flash_attention — exact softmax attention oracle
# ---------------------------------------------------------------------------


def mha_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
            causal: bool = True, window: int | None = None,
            scale: float | None = None) -> jnp.ndarray:
    """Exact attention.  q: (B, H, S, D); k,v: (B, H, Skv, D) (kv already
    repeated to H heads).  window = local attention span (None = global)."""
    b, h, s, d = q.shape
    skv = k.shape[2]
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    logits = jnp.einsum("bhsd,bhtd->bhst", q, k).astype(jnp.float32) * scale
    qpos = jnp.arange(s)[:, None] + (skv - s)   # align last q with last k
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((s, skv), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask, logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)  # fully-masked rows
    # probs stored/saved in the value dtype (bf16): halves the dominant
    # backward residual; matches the fused-kernel numerics on real TPUs
    p = p.astype(v.dtype)
    return jnp.einsum("bhst,bhtd->bhsd", p, v).astype(q.dtype)


def mha_chunked(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                causal: bool = True, window: int | None = None,
                scale: float | None = None,
                q_chunk: int = 1024) -> jnp.ndarray:
    """Memory-safe exact attention for long sequences: lax.scan over query
    chunks so the live score tensor is (B, H, q_chunk, Skv) instead of
    (B, H, S, Skv).  XLA path used by 32k prefill (and anything ≥ 16k)."""
    b, h, s, d = q.shape
    dv = v.shape[-1]            # MLA: v_head_dim may differ from qk dim
    skv = k.shape[2]
    scale_ = scale if scale is not None else 1.0 / (d ** 0.5)
    if s % q_chunk or s <= q_chunk:
        return mha_ref(q, k, v, causal, window, scale)
    nq = s // q_chunk
    qs = q.reshape(b, h, nq, q_chunk, d).transpose(2, 0, 1, 3, 4)
    kpos = jnp.arange(skv)[None, :]

    def one(carry, args):
        qi, qc = args
        logits = jnp.einsum("bhsd,bhtd->bhst", qc, k).astype(jnp.float32) \
            * scale_
        qpos = (qi * q_chunk + jnp.arange(q_chunk))[:, None] + (skv - s)
        mask = jnp.ones((q_chunk, skv), dtype=bool)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        logits = jnp.where(mask, logits, -jnp.inf)
        p = jax.nn.softmax(logits, axis=-1)
        p = jnp.where(jnp.isnan(p), 0.0, p).astype(v.dtype)
        o = jnp.einsum("bhst,bhtd->bhsd", p, v)
        return carry, o.astype(q.dtype)

    # scanned (not unrolled): the live score tensor stays one chunk.
    # cost_analysis counts the body once — the roofline adds the known
    # (nq−1)× analytic correction for prefill cells (launch/roofline.py).
    _, outs = jax.lax.scan(one, (),
                           (jnp.arange(nq, dtype=jnp.int32), qs))
    return outs.transpose(1, 2, 0, 3, 4).reshape(b, h, s, dv)
