# Pallas TPU kernels for the compute hot-spots (validated in interpret
# mode on CPU; Mosaic lowering on real TPUs):
#   bsr_spmv.py        — block-sparse semiring SpMV (the NALE array)
#   flash_attention.py — fused causal/local attention
#   wkv6.py            — RWKV-6 data-dependent-decay state recurrence
# ops.py = jit'd dispatching wrappers; ref.py = pure-jnp oracles.
