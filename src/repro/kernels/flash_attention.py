"""Fused flash-attention Pallas kernel (causal / sliding-window).

TPU-native tiling: grid (batch·heads, q_blocks, k_blocks) with the k-block
axis innermost (sequential), online-softmax running max / denominator /
accumulator held in VMEM scratch across k-steps.  BlockSpecs stage
(bq, d) / (bk, d) tiles HBM→VMEM; fully-masked k-blocks are skipped at
block granularity (causal upper triangle and out-of-window blocks cost
nothing — the same "work ∝ actual dependencies" principle as the paper's
self-timed NALEs, here applied to the attention dependency graph).

Requires sq == skv (training / prefill).  Decode uses the XLA path in
``ops.attention``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # pltpu is importable on CPU; only lowering needs real TPUs
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    _VMEM = None

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, window: int | None,
                  bq: int, bk: int, nk: int):
    qi, ki = pl.program_id(1), pl.program_id(2)

    @pl.when(ki == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * bq
    k_start = ki * bk
    run = jnp.bool_(True)
    if causal:  # skip blocks strictly above the diagonal band
        run &= k_start <= q_start + bq - 1
    if window is not None:  # skip blocks left of the window
        run &= k_start + bk > q_start - window

    @pl.when(run)
    def _():
        q = q_ref[0].astype(jnp.float32) * scale           # (bq, d)
        k = k_ref[0].astype(jnp.float32)                   # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), dtype=bool)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, 0]                               # (bq,)
        l_prev = l_ref[:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=1)
        v = v_ref[0].astype(jnp.float32)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + pv
        m_ref[:, 0] = m_new
        l_ref[:, 0] = l_new

    @pl.when(ki == nk - 1)
    def _():
        l = l_ref[:, 0]
        denom = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "scale", "bq", "bk", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True, window: int | None = None,
                    scale: float | None = None, bq: int = 128, bk: int = 128,
                    interpret: bool = True) -> jnp.ndarray:
    """q, k, v: (B, H, S, D) with kv heads already repeated to H.

    Pads S to a multiple of the block size; padded key rows are masked via
    the causal/window predicate plus an explicit validity clamp (padded q
    rows are sliced off on return).
    """
    b, h, s, d = q.shape
    assert k.shape == (b, h, s, d) and v.shape == (b, h, s, d)
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    bq = min(bq, max(8, s))
    bk = min(bk, max(8, s))
    s_pad = ((s + max(bq, bk) - 1) // max(bq, bk)) * max(bq, bk)
    if s_pad != s:
        pad = ((0, 0), (0, 0), (0, s_pad - s), (0, 0))
        q, k, v = (jnp.pad(t, pad) for t in (q, k, v))
        if not causal:
            # without causal masking, padded keys would attend; use a window
            # trick only if given, else mask by clamping k beyond s:
            pass
    nq, nk = s_pad // bq, s_pad // bk
    qr = q.reshape(b * h, s_pad, d)
    kr = k.reshape(b * h, s_pad, d)
    vr = v.reshape(b * h, s_pad, d)
    grid = (b * h, nq, nk)
    kern = functools.partial(_flash_kernel, scale=scale, causal=causal,
                             window=window, bq=bq, bk=bk, nk=nk)
    scratch = [_VMEM((bq, d), jnp.float32), _VMEM((bq, 1), jnp.float32),
               _VMEM((bq, 1), jnp.float32)]
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, qi, ki: (bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s_pad, d), q.dtype),
        scratch_shapes=scratch,
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, h, s_pad, d)[:, :, :s, :]
