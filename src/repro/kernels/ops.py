"""Public jit'd kernel entry points behind one ``select_kernel`` registry.

Pallas-Mosaic lowers only on TPU; this container is CPU, so:
  * default path (``KernelSpec(impl="ref")``) is the pure-jnp oracle,
    which XLA fuses — this is also what the multi-pod dry-run lowers
    (Pallas calls cannot be SPMD-partitioned across a 512-device host
    mesh);
  * ``impl="pallas"`` runs the kernel (interpret=True off-TPU, compiled
    on TPU) — tests sweep it against the reference.

Engines no longer string-match ``impl`` inline: they resolve a callable
once per trace via ``select_kernel(op, spec)``, where ``spec`` is a
``KernelSpec`` (kernels/spec.py).  Every registered builder receives the
resolved platform, so the interpret-mode fallback off-TPU is decided in
exactly one place (``use_interpret``) for the graph kernels AND
attention.

Registered call signatures (one contract per (op, fused) pair):

  ("bsr_spmv", fused=False)  fn(vals, cols, nnz, x, semiring=...)
                             -> y (R, B)
  ("bsr_spmv", fused=True)   fn(vals, cols, nnz, x, xg, valid, act_rows,
                                damping, tol, inv_n, semiring=...,
                                apply_kind=...)
                             -> (x_new, changed, improved_any)
  ("attention", fused=False) fn(q, k, v, causal, window, scale, bq, bk)
                             -> o   (kv heads already GQA-repeated)

The legacy ``bsr_spmv(..., impl=...)`` / ``attention(..., impl=...)``
wrappers below keep the historical signatures and route through the same
registry.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from . import ref as _ref
from .. import resilience
from .bsr_spmv import bsr_spmv as _bsr_spmv_pallas
from .bsr_spmv import bsr_spmv_fused as _bsr_spmv_fused
from .flash_attention import flash_attention as _flash_pallas
from .spec import DEFAULT_BLOCK_SIZE, KernelSpec, as_kernel_spec


# ---------------------------------------------------------------------------
# platform guard — the one place that decides interpret-mode fallback
# ---------------------------------------------------------------------------


def resolve_platform(platform: Optional[str] = None) -> str:
    if platform is not None:
        return platform
    try:
        return jax.default_backend()
    except Exception:  # pragma: no cover
        return "cpu"


def use_interpret(platform: Optional[str] = None) -> bool:
    """Mosaic lowers only on TPU; every other backend (this CPU
    container, GPU) runs Pallas kernels in interpret mode."""
    return resolve_platform(platform) != "tpu"


def _on_tpu() -> bool:  # legacy spelling, kept for external callers
    return not use_interpret()


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_KERNELS = {}


def register_kernel(op: str, impl: str, fused: bool = False):
    def deco(builder):
        _KERNELS[(op, impl, fused)] = builder
        return builder
    return deco


def select_kernel(op: str, spec=None, platform: Optional[str] = None):
    """Resolve one kernel callable for (op, spec) on a platform.

    ``spec`` may be a ``KernelSpec``, a bare impl string, or None
    (defaults).  Raises ``KeyError`` naming the available registrations
    when the combination has no kernel.

    Fault site ``kernel.select`` fires here (ctx: op/impl/fused) — the
    dispatch/trace-time failure the ``ExecutionPolicy`` degradation
    ladder absorbs by re-running on the ``ref`` kernel.  Note jit
    caching: engines resolve kernels while tracing, so the site is hit
    once per (engine, kernel, shape) compilation, not once per query.
    """
    spec = as_kernel_spec(spec)
    resilience.fire("kernel.select", op=op, impl=spec.impl,
                    fused=spec.fuse_frontier)
    key = (op, spec.impl, spec.fuse_frontier)
    try:
        builder = _KERNELS[key]
    except KeyError:
        raise KeyError(
            f"no kernel registered for op={op!r} impl={spec.impl!r} "
            f"fused={spec.fuse_frontier}; have {sorted(_KERNELS)}"
        ) from None
    return builder(spec, resolve_platform(platform))


# ---------------------------------------------------------------------------
# bsr_spmv
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("semiring",))
def _bsr_spmv_ref_jit(block_vals, block_cols, x, semiring):
    return _ref.bsr_spmv_ref(block_vals, block_cols, x, semiring)


@register_kernel("bsr_spmv", "ref")
def _build_bsr_spmv_ref(spec: KernelSpec, platform: str):
    del spec, platform  # XLA path: no tiling knobs, any backend

    def fn(block_vals, block_cols, block_nnz, x, semiring="plus_times"):
        del block_nnz  # identity padding makes the bound implicit
        return _bsr_spmv_ref_jit(block_vals, block_cols, x, semiring)

    return fn


@register_kernel("bsr_spmv", "pallas")
def _build_bsr_spmv_pallas(spec: KernelSpec, platform: str):
    interpret = use_interpret(platform)
    bk = spec.block_size or DEFAULT_BLOCK_SIZE
    rs = spec.rows_per_step or 1

    def fn(block_vals, block_cols, block_nnz, x, semiring="plus_times"):
        return _bsr_spmv_pallas(block_vals, block_cols, block_nnz, x,
                                semiring=semiring, bk=bk, rows_per_step=rs,
                                interpret=interpret)

    return fn


@register_kernel("bsr_spmv", "pallas", fused=True)
def _build_bsr_spmv_fused(spec: KernelSpec, platform: str):
    interpret = use_interpret(platform)
    bk = spec.block_size or DEFAULT_BLOCK_SIZE

    def fn(block_vals, block_cols, block_nnz, x, xg, valid, act_rows,
           damping, tol, inv_n, semiring="min_plus", apply_kind="relax"):
        return _bsr_spmv_fused(block_vals, block_cols, block_nnz, x, xg,
                               valid, act_rows, damping, tol, inv_n,
                               semiring=semiring, apply_kind=apply_kind,
                               bk=bk, interpret=interpret)

    return fn


def bsr_spmv(block_vals, block_cols, block_nnz, x, semiring="plus_times",
             impl="ref", bk=8):
    """Block-sparse semiring SpMV.  See kernels/bsr_spmv.py for layout.

    Legacy entry point: ``impl``/``bk`` build a ``KernelSpec``; engines
    use ``select_kernel`` directly.
    """
    spec = KernelSpec(impl=impl, block_size=bk if impl == "pallas"
                      else None)
    fn = select_kernel("bsr_spmv", spec)
    return fn(block_vals, block_cols, block_nnz, x, semiring=semiring)


def bsr_spmv_fused(block_vals, block_cols, block_nnz, x, xg, valid,
                   act_rows, damping, tol, inv_n, semiring="min_plus",
                   apply_kind="relax", spec: Optional[KernelSpec] = None):
    """Fused frontier-masked sweep (see bsr_spmv.bsr_spmv_fused)."""
    spec = spec or KernelSpec(impl="pallas", fuse_frontier=True)
    fn = select_kernel("bsr_spmv", spec)
    return fn(block_vals, block_cols, block_nnz, x, xg, valid, act_rows,
              damping, tol, inv_n, semiring=semiring,
              apply_kind=apply_kind)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


CHUNKED_THRESHOLD = 16384


def _attention_ref(q, k, v, causal, window, scale, bq, bk):
    del bq, bk
    if q.shape[2] >= CHUNKED_THRESHOLD:
        return _ref.mha_chunked(q, k, v, causal=causal, window=window,
                                scale=scale)
    return _ref.mha_ref(q, k, v, causal=causal, window=window, scale=scale)


@register_kernel("attention", "ref")
def _build_attention_ref(spec: KernelSpec, platform: str):
    del spec, platform
    return _attention_ref


@register_kernel("attention", "pallas")
def _build_attention_pallas(spec: KernelSpec, platform: str):
    del spec
    interpret = use_interpret(platform)

    def fn(q, k, v, causal, window, scale, bq, bk):
        s, d = q.shape[2], q.shape[3]
        # The flash kernel assumes S == Skv (train/prefill) and
        # d_v == d_qk; decode and MLA shapes use the XLA path.
        if s == k.shape[2] and s > 1 and v.shape[-1] == d:
            return _flash_pallas(q, k, v, causal=causal, window=window,
                                 scale=scale, bq=bq, bk=bk,
                                 interpret=interpret)
        return _attention_ref(q, k, v, causal, window, scale, bq, bk)

    return fn


def attention(q, k, v, causal=True, window=None, scale=None, impl="ref",
              bq=128, bk=128):
    """Multi-head attention; q (B,H,S,D), k/v (B,Hkv,Skv,D).

    Repeats kv heads for GQA, then dispatches through the kernel
    registry — the Pallas path shares the graph kernels' platform guard
    (interpret off-TPU), falling back to the XLA path for shapes the
    flash kernel does not support.  Long sequences take the chunked-exact
    XLA path so the score tensor never materializes at (S, S).
    """
    h = q.shape[1]
    hkv = k.shape[1]
    if hkv != h:
        rep = h // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    fn = select_kernel("attention", KernelSpec(impl=impl))
    return fn(q, k, v, causal, window, scale, bq, bk)
