"""Public jit'd kernel entry points with backend dispatch.

Pallas-Mosaic lowers only on TPU; this container is CPU, so:
  * default path (`impl="ref"`) is the pure-jnp oracle, which XLA fuses —
    this is also what the multi-pod dry-run lowers (Pallas calls cannot be
    SPMD-partitioned across a 512-device host mesh);
  * `impl="pallas"` runs the kernel (interpret=True on CPU, compiled on
    TPU) — tests sweep it against the reference.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref as _ref
from .bsr_spmv import bsr_spmv as _bsr_spmv_pallas
from .flash_attention import flash_attention as _flash_pallas


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover
        return False


# ---------------------------------------------------------------------------
# bsr_spmv
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("semiring",))
def _bsr_spmv_ref_jit(block_vals, block_cols, x, semiring):
    return _ref.bsr_spmv_ref(block_vals, block_cols, x, semiring)


def bsr_spmv(block_vals, block_cols, block_nnz, x, semiring="plus_times",
             impl="ref", bk=8):
    """Block-sparse semiring SpMV.  See kernels/bsr_spmv.py for layout."""
    if impl == "pallas":
        return _bsr_spmv_pallas(block_vals, block_cols, block_nnz, x,
                                semiring=semiring, bk=bk,
                                interpret=not _on_tpu())
    return _bsr_spmv_ref_jit(block_vals, block_cols, x, semiring)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


CHUNKED_THRESHOLD = 16384


def attention(q, k, v, causal=True, window=None, scale=None, impl="ref",
              bq=128, bk=128):
    """Multi-head attention; q (B,H,S,D), k/v (B,Hkv,Skv,D).

    Repeats kv heads for GQA, then dispatches kernel/reference.  The Pallas
    path requires S == Skv (train/prefill); decode always uses the XLA
    path.  Long sequences take the chunked-exact XLA path so the score
    tensor never materializes at (S, S).
    """
    bsz, h, s, d = q.shape
    hkv = k.shape[1]
    if hkv != h:
        rep = h // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    if impl == "pallas" and s == k.shape[2] and s > 1 \
            and v.shape[-1] == d:  # flash kernel assumes d_v == d_qk
        return _flash_pallas(q, k, v, causal=causal, window=window,
                             scale=scale, bq=bq, bk=bk,
                             interpret=not _on_tpu())
    if s >= CHUNKED_THRESHOLD:
        return _ref.mha_chunked(q, k, v, causal=causal, window=window,
                                scale=scale)
    return _ref.mha_ref(q, k, v, causal=causal, window=window, scale=scale)
