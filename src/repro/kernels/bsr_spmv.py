"""Block-sparse semiring SpMV Pallas kernel — the NALE array on TPU.

Paper mapping.  The NALE is a MAC-plus-comparator engine fed by FIFOs; a
NALE in *cluster mode* executes a whole node cluster.  After the clustering
pass densifies edges into B×B tiles (see ``core/cluster.py``), one tile is
exactly one cluster-mode NALE work item: a dense semiring MAC between a
tile of edges and a block of source-node values.  The systolic array of
NALEs becomes the MXU (plus_times) / VPU (min_plus, max_min), VMEM plays
the NALE-local FIFO store, and the *self-timed* property — work driven by
actual data, not worst case — is realized by bounding each row-block's
inner loop with its true tile count (``block_nnz``): empty FIFO slots cost
nothing.

Layout (ELL-of-tiles):
  block_vals : (R, K, B, B)  tile values, padded with the ⊕-identity
  block_cols : (R, K) int32  col-block index per tile
  block_nnz  : (R,)   int32  true tile count per row-block
  x          : (C, B)        input node values (block layout)
  y          : (R, B)        output

Grid: ``(R, K // bk)`` — row-blocks × tile-chunks.  The tile-chunk axis is
innermost (sequential on TPU), accumulating into the output block that
stays resident in VMEM; BlockSpecs stage (1, bk, B, B) value slabs
HBM→VMEM per step.  ``x`` is kept whole in VMEM (graph shards are sized so
a shard's node values fit: C·B·4 bytes ≤ a few MB — the same constraint
the paper's per-NALE FIFO capacity imposes).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _init_val(semiring: str) -> float:
    return {"plus_times": 0.0, "min_plus": jnp.inf,
            "max_min": 0.0, "min_select": jnp.inf}[semiring]


def _tile_combine(semiring: str, tile, xb):
    """One NALE MAC: combine (bk,B,B) tiles with (bk,B) gathered x blocks,
    reduce over the tile-chunk and source axes -> (B,) partial."""
    if semiring == "plus_times":
        # (bk,B,B) @ (bk,B) -> (bk,B) -> (B,)
        return jnp.einsum("kij,kj->i", tile, xb,
                          preferred_element_type=jnp.float32)
    if semiring == "min_plus":
        return jnp.min(tile + xb[:, None, :], axis=(0, 2))
    if semiring == "max_min":
        return jnp.max(jnp.minimum(tile, xb[:, None, :]), axis=(0, 2))
    if semiring == "min_select":
        t = jnp.where(jnp.isfinite(tile), xb[:, None, :], jnp.inf)
        return jnp.min(t, axis=(0, 2))
    raise ValueError(semiring)


def _acc(semiring: str, a, b):
    if semiring == "plus_times":
        return a + b
    if semiring in ("min_plus", "min_select"):
        return jnp.minimum(a, b)
    return jnp.maximum(a, b)


def _gather_combine(semiring: str, bk: int, nnz, base, tile, cols, x_ref):
    """Gather the source-node blocks for one (bk,) tile chunk and combine.
    K is small (≤ bk), so an unrolled gather over bk dynamic row loads
    maps to bk VMEM dynamic slices."""
    xb = jnp.stack([pl.load(x_ref, (pl.dslice(cols[t], 1), slice(None)))[0]
                    for t in range(bk)])  # (bk, B)
    # mask padded lanes of the *final* chunk with ⊕-identity values —
    # padding tiles already hold identities, but their gathered x could
    # combine under min_select; keep it exact:
    lane = jnp.arange(bk) + base
    live = (lane < nnz)[:, None, None]
    tile = jnp.where(live, tile, _init_val(semiring))
    return _tile_combine(semiring, tile, xb)


def _bsr_spmv_kernel(nnz_ref, cols_ref, vals_ref, x_ref, y_ref, *,
                     semiring: str, bk: int, rows_per_step: int):
    r, kc = pl.program_id(0), pl.program_id(1)

    @pl.when(kc == 0)
    def _():
        y_ref[...] = jnp.full_like(y_ref, _init_val(semiring))

    base = kc * bk
    for rr in range(rows_per_step):
        # Self-timed bound: only true tiles are combined.  ``nnz`` comes
        # from a blocked spec so the scalar is already in SMEM-like storage.
        nnz = nnz_ref[rr]
        valid = jnp.clip(nnz - base, 0, bk)

        @pl.when(valid > 0)
        def _(rr=rr, nnz=nnz):
            part = _gather_combine(semiring, bk, nnz, base, vals_ref[rr],
                                   cols_ref[rr], x_ref)
            y_ref[rr, :] = _acc(semiring, y_ref[rr, :], part)


@functools.partial(jax.jit, static_argnames=(
    "semiring", "bk", "rows_per_step", "interpret"))
def bsr_spmv(block_vals: jnp.ndarray, block_cols: jnp.ndarray,
             block_nnz: jnp.ndarray, x: jnp.ndarray,
             semiring: str = "plus_times", bk: int = 8,
             rows_per_step: int = 1,
             interpret: bool = True) -> jnp.ndarray:
    """Pallas block-sparse semiring SpMV.  See module docstring for layout.

    ``rows_per_step`` coarsens the grid: each step stages (and relaxes)
    that many row-blocks, trading grid-step overhead for VMEM residency.
    """
    r, k, b, _ = block_vals.shape
    rs = max(int(rows_per_step), 1)
    if k % bk:
        pad = bk - k % bk
        block_vals = jnp.pad(block_vals, ((0, 0), (0, pad), (0, 0), (0, 0)),
                             constant_values=_init_val(semiring))
        block_cols = jnp.pad(block_cols, ((0, 0), (0, pad)))
        k += pad
    r_out = r
    if r % rs:
        pad_r = rs - r % rs
        block_vals = jnp.pad(block_vals, ((0, pad_r),) + ((0, 0),) * 3,
                             constant_values=_init_val(semiring))
        block_cols = jnp.pad(block_cols, ((0, pad_r), (0, 0)))
        block_nnz = jnp.pad(block_nnz, (0, pad_r))  # nnz=0: never combined
        r += pad_r
    c = x.shape[0]
    grid = (r // rs, k // bk)
    y = pl.pallas_call(
        functools.partial(_bsr_spmv_kernel, semiring=semiring, bk=bk,
                          rows_per_step=rs),
        grid=grid,
        in_specs=[
            pl.BlockSpec((rs,), lambda r, kc: (r,)),                    # nnz
            pl.BlockSpec((rs, bk), lambda r, kc: (r, kc)),              # cols
            pl.BlockSpec((rs, bk, b, b), lambda r, kc: (r, kc, 0, 0)),  # vals
            pl.BlockSpec((c, b), lambda r, kc: (0, 0)),                 # x
        ],
        out_specs=pl.BlockSpec((rs, b), lambda r, kc: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((r, b), jnp.float32),
        interpret=interpret,
    )(block_nnz, block_cols, block_vals.astype(jnp.float32),
      x.astype(jnp.float32))
    return y[:r_out] if r_out != r else y


# ---------------------------------------------------------------------------
# fused relax + frontier-select + convergence-reduce with active-tile skip
# ---------------------------------------------------------------------------
#
# One kernel per sweep instead of SpMV + separate XLA apply/mask/reduce
# ops.  Active-tile skipping: the caller passes the active row-block mask
# (rows with at least one live tile reading a changed source block); the
# wrapper compacts it into an index list prefetched as scalars, and the
# grid walks ONLY those rows — the paper's self-timed "empty FIFO slots
# cost nothing" at row-block granularity.  Grid steps beyond the active
# count are clamped onto the last active row (same block index ⇒ Mosaic
# re-fetches nothing) and fully predicated off with ``pl.when``.
#
# In-place frontier semantics: the output x aliases a *copy* of the input
# row values, so rows absent from the active list pass through untouched,
# while the kernel reads old values from the separate, unaliased full-x
# operand — exact Jacobi, bit-identical to the unfused path (rows whose
# inputs didn't change would recompute the same value anyway; idempotent
# ⊕ covers self-value reads).

# the update rules below mirror core/engine._apply op-for-op (same jnp
# primitives ⇒ same lowering ⇒ bit-identical results); they live here
# because kernels/ must not import core/ (core.__init__ imports engine,
# which imports kernels.ops)


def _improves(semiring: str, new, old):
    if semiring == "plus_times":
        return new != old
    if semiring == "max_min":
        return new > old
    return new < old  # min_plus, min_select


def _apply_rows(apply_kind: str, semiring: str, y, xg, vg, damping, inv_n,
                tol):
    """(x_new, improved) for one row-block; mirrors core/engine._apply."""
    if apply_kind == "relax":
        x_new = _acc(semiring, y, xg)   # _acc IS the ⊕ of the semiring
        imp = _improves(semiring, x_new, xg)
    elif apply_kind == "pagerank":
        x_new = (1.0 - damping) * inv_n + damping * y
        x_new = jnp.where(vg, x_new, 0.0)
        imp = jnp.abs(x_new - xg) > tol
    elif apply_kind == "pagerank_delta":
        cand = (1.0 - damping) * inv_n + damping * y
        imp = (cand - xg) > tol
        x_new = jnp.where(imp, cand, xg)
    elif apply_kind == "kcore":
        alive = (xg > 0.0) & (y >= damping)
        x_new = jnp.where(alive, xg, 0.0)
        imp = x_new < xg
    elif apply_kind == "identity":
        x_new = jnp.where(vg, y, xg)
        imp = _improves(semiring, x_new, xg)
    else:
        raise ValueError(apply_kind)
    x_new = jnp.where(vg, x_new, xg)
    imp = imp & vg
    return x_new, imp


def _fused_kernel(na_ref, al_ref, nnz_ref, cols_ref, vals_ref, x_ref,
                  xg_ref, valid_ref, par_ref, xa_ref, ch0_ref,
                  xo_ref, cho_ref, conv_ref, *,
                  semiring: str, apply_kind: str, bk: int, nk: int):
    i, kc = pl.program_id(0), pl.program_id(1)
    del xa_ref, ch0_ref  # aliased output bases; never read in-kernel

    @pl.when((i == 0) & (kc == 0))
    def _():
        conv_ref[0] = False

    live_step = i < na_ref[0]

    # accumulate the ⊕-reduction in the aliased x-out block; the old row
    # values stay readable in the unaliased xg operand until the apply
    @pl.when(live_step & (kc == 0))
    def _():
        xo_ref[0, :] = jnp.full_like(xo_ref[0, :], _init_val(semiring))

    nnz = nnz_ref[0]
    base = kc * bk
    valid_n = jnp.clip(nnz - base, 0, bk)

    @pl.when(live_step & (valid_n > 0))
    def _():
        part = _gather_combine(semiring, bk, nnz, base, vals_ref[0],
                               cols_ref[0], x_ref)
        xo_ref[0, :] = _acc(semiring, xo_ref[0, :], part)

    @pl.when(live_step & (kc == nk - 1))
    def _():
        y = xo_ref[0, :]
        xg = xg_ref[0, :]
        vg = valid_ref[0, :]
        x_new, imp = _apply_rows(apply_kind, semiring, y, xg, vg,
                                 par_ref[0], par_ref[2], par_ref[1])
        xo_ref[0, :] = x_new
        imp_any = jnp.any(imp)
        cho_ref[0] = cho_ref[0] | imp_any
        conv_ref[0] = conv_ref[0] | imp_any


@functools.partial(jax.jit, static_argnames=(
    "semiring", "apply_kind", "bk", "interpret"))
def bsr_spmv_fused(block_vals: jnp.ndarray, block_cols: jnp.ndarray,
                   block_nnz: jnp.ndarray, x: jnp.ndarray,
                   xg: jnp.ndarray, valid: jnp.ndarray,
                   act_rows: jnp.ndarray, damping, tol, inv_n,
                   semiring: str = "min_plus", apply_kind: str = "relax",
                   bk: int = 8, interpret: bool = True):
    """One fused frontier-masked sweep over the active row-blocks.

    Args:
      block_vals/block_cols/block_nnz: (R, K, B, B)/(R, K)/(R,) BSR rows.
      x: (C, B) full source-node values (read-only, previous sweep).
      xg: (R, B) current values of THESE rows (``x`` itself for the
        whole-graph sync engine; the group slice for the async engine).
      valid: (R, B) bool — real (non-padding) vertices.
      act_rows: (R,) bool — rows to relax this sweep (the frontier rule:
        any live tile reads a changed source block).
      damping/tol/inv_n: apply-rule scalars (PageRank).
    Returns:
      x_new (R, B) — relaxed active rows, other rows passed through;
      changed (R,) bool — rows the apply rule improved (next frontier);
      improved_any () bool — fused convergence flag (``changed.any()``).
    """
    r, k, b, _ = block_vals.shape
    if k % bk:
        pad = bk - k % bk
        block_vals = jnp.pad(block_vals, ((0, 0), (0, pad), (0, 0), (0, 0)),
                             constant_values=_init_val(semiring))
        block_cols = jnp.pad(block_cols, ((0, 0), (0, pad)))
        k += pad
    c = x.shape[0]
    nk = k // bk

    # compact active list: active rows first (stable ⇒ deterministic),
    # tail steps clamped onto the last active row and predicated off
    act_rows = act_rows.astype(bool)
    order = jnp.argsort(~act_rows, stable=True).astype(jnp.int32)
    na = jnp.sum(act_rows).astype(jnp.int32)
    idx = jnp.minimum(jnp.arange(r, dtype=jnp.int32),
                      jnp.maximum(na - 1, 0))
    active_list = order[idx]
    params = jnp.stack([jnp.float32(damping), jnp.float32(tol),
                        jnp.float32(inv_n)])

    xg = xg.astype(jnp.float32)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(r, nk),
        in_specs=[
            pl.BlockSpec((1,), lambda i, kc, na, al: (al[i],)),    # nnz
            pl.BlockSpec((1, bk), lambda i, kc, na, al: (al[i], kc)),
            pl.BlockSpec((1, bk, b, b),
                         lambda i, kc, na, al: (al[i], kc, 0, 0)),  # vals
            pl.BlockSpec((c, b), lambda i, kc, na, al: (0, 0)),     # x
            pl.BlockSpec((1, b), lambda i, kc, na, al: (al[i], 0)),  # xg
            pl.BlockSpec((1, b), lambda i, kc, na, al: (al[i], 0)),  # valid
            pl.BlockSpec((3,), lambda i, kc, na, al: (0,)),         # params
            pl.BlockSpec((1, b), lambda i, kc, na, al: (al[i], 0)),  # x alias
            pl.BlockSpec((1,), lambda i, kc, na, al: (al[i],)),     # ch alias
        ],
        out_specs=[
            pl.BlockSpec((1, b), lambda i, kc, na, al: (al[i], 0)),  # x_new
            pl.BlockSpec((1,), lambda i, kc, na, al: (al[i],)),     # changed
            pl.BlockSpec((1,), lambda i, kc, na, al: (0,)),         # conv
        ])
    x_new, changed, conv = pl.pallas_call(
        functools.partial(_fused_kernel, semiring=semiring,
                          apply_kind=apply_kind, bk=bk, nk=nk),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((r, b), jnp.float32),
                   jax.ShapeDtypeStruct((r,), jnp.bool_),
                   jax.ShapeDtypeStruct((1,), jnp.bool_)],
        # operand indices COUNT the scalar-prefetch operands (na, al):
        # 9 = the xg copy aliased onto x_new, 10 = the zero changed-bits
        input_output_aliases={9: 0, 10: 1},
        interpret=interpret,
    )(jnp.reshape(na, (1,)), active_list,
      block_nnz, block_cols, block_vals.astype(jnp.float32),
      x.astype(jnp.float32), xg, valid, params,
      xg, jnp.zeros((r,), dtype=jnp.bool_))
    return x_new, changed, conv[0]
