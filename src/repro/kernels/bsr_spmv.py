"""Block-sparse semiring SpMV Pallas kernel — the NALE array on TPU.

Paper mapping.  The NALE is a MAC-plus-comparator engine fed by FIFOs; a
NALE in *cluster mode* executes a whole node cluster.  After the clustering
pass densifies edges into B×B tiles (see ``core/cluster.py``), one tile is
exactly one cluster-mode NALE work item: a dense semiring MAC between a
tile of edges and a block of source-node values.  The systolic array of
NALEs becomes the MXU (plus_times) / VPU (min_plus, max_min), VMEM plays
the NALE-local FIFO store, and the *self-timed* property — work driven by
actual data, not worst case — is realized by bounding each row-block's
inner loop with its true tile count (``block_nnz``): empty FIFO slots cost
nothing.

Layout (ELL-of-tiles):
  block_vals : (R, K, B, B)  tile values, padded with the ⊕-identity
  block_cols : (R, K) int32  col-block index per tile
  block_nnz  : (R,)   int32  true tile count per row-block
  x          : (C, B)        input node values (block layout)
  y          : (R, B)        output

Grid: ``(R, K // bk)`` — row-blocks × tile-chunks.  The tile-chunk axis is
innermost (sequential on TPU), accumulating into the output block that
stays resident in VMEM; BlockSpecs stage (1, bk, B, B) value slabs
HBM→VMEM per step.  ``x`` is kept whole in VMEM (graph shards are sized so
a shard's node values fit: C·B·4 bytes ≤ a few MB — the same constraint
the paper's per-NALE FIFO capacity imposes).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _init_val(semiring: str) -> float:
    return {"plus_times": 0.0, "min_plus": jnp.inf,
            "max_min": 0.0, "min_select": jnp.inf}[semiring]


def _tile_combine(semiring: str, tile, xb):
    """One NALE MAC: combine (bk,B,B) tiles with (bk,B) gathered x blocks,
    reduce over the tile-chunk and source axes -> (B,) partial."""
    if semiring == "plus_times":
        # (bk,B,B) @ (bk,B) -> (bk,B) -> (B,)
        return jnp.einsum("kij,kj->i", tile, xb,
                          preferred_element_type=jnp.float32)
    if semiring == "min_plus":
        return jnp.min(tile + xb[:, None, :], axis=(0, 2))
    if semiring == "max_min":
        return jnp.max(jnp.minimum(tile, xb[:, None, :]), axis=(0, 2))
    if semiring == "min_select":
        t = jnp.where(jnp.isfinite(tile), xb[:, None, :], jnp.inf)
        return jnp.min(t, axis=(0, 2))
    raise ValueError(semiring)


def _acc(semiring: str, a, b):
    if semiring == "plus_times":
        return a + b
    if semiring in ("min_plus", "min_select"):
        return jnp.minimum(a, b)
    return jnp.maximum(a, b)


def _bsr_spmv_kernel(nnz_ref, cols_ref, vals_ref, x_ref, y_ref, *,
                     semiring: str, bk: int):
    r, kc = pl.program_id(0), pl.program_id(1)

    @pl.when(kc == 0)
    def _():
        y_ref[...] = jnp.full_like(y_ref, _init_val(semiring))

    # Self-timed bound: only true tiles are combined.  ``nnz`` comes from a
    # (1,)-blocked spec so the scalar is already in SMEM-like storage.
    nnz = nnz_ref[0]
    base = kc * bk
    valid = jnp.clip(nnz - base, 0, bk)

    @pl.when(valid > 0)
    def _():
        # Gather the source-node blocks for this tile chunk.  K is small
        # (≤ bk), so an unrolled gather over bk dynamic row loads maps to
        # bk VMEM dynamic slices.
        tile = vals_ref[0]          # (bk, B, B)
        cols = cols_ref[0]          # (bk,)
        xb = jnp.stack([pl.load(x_ref, (pl.dslice(cols[t], 1), slice(None)))[0]
                        for t in range(bk)])  # (bk, B)
        # mask padded lanes of the *final* chunk with ⊕-identity values —
        # padding tiles already hold identities, but their gathered x could
        # combine under min_select; keep it exact:
        lane = jnp.arange(bk) + base
        live = (lane < nnz)[:, None, None]
        tile = jnp.where(live, tile, _init_val(semiring))
        part = _tile_combine(semiring, tile, xb)
        y_ref[0, :] = _acc(semiring, y_ref[0, :], part)


@functools.partial(jax.jit, static_argnames=("semiring", "bk", "interpret"))
def bsr_spmv(block_vals: jnp.ndarray, block_cols: jnp.ndarray,
             block_nnz: jnp.ndarray, x: jnp.ndarray,
             semiring: str = "plus_times", bk: int = 8,
             interpret: bool = True) -> jnp.ndarray:
    """Pallas block-sparse semiring SpMV.  See module docstring for layout."""
    r, k, b, _ = block_vals.shape
    if k % bk:
        pad = bk - k % bk
        block_vals = jnp.pad(block_vals, ((0, 0), (0, pad), (0, 0), (0, 0)),
                             constant_values=_init_val(semiring))
        block_cols = jnp.pad(block_cols, ((0, 0), (0, pad)))
        k += pad
    c = x.shape[0]
    grid = (r, k // bk)
    return pl.pallas_call(
        functools.partial(_bsr_spmv_kernel, semiring=semiring, bk=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda r, kc: (r,)),                    # nnz
            pl.BlockSpec((1, bk), lambda r, kc: (r, kc)),              # cols
            pl.BlockSpec((1, bk, b, b), lambda r, kc: (r, kc, 0, 0)),  # vals
            pl.BlockSpec((c, b), lambda r, kc: (0, 0)),                # x
        ],
        out_specs=pl.BlockSpec((1, b), lambda r, kc: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((r, b), jnp.float32),
        interpret=interpret,
    )(block_nnz, block_cols, block_vals.astype(jnp.float32),
      x.astype(jnp.float32))
