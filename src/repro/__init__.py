"""repro — asynchronous graph-processor architecture (Kinsy et al. 2017)
as a production multi-pod JAX framework.  See DESIGN.md.

NOTE: this package must stay import-light (no jax device init at import
time) — launch/dryrun.py sets XLA_FLAGS before first jax use.
"""
