"""Analytic FLOP/byte model per (arch × shape) — the MODEL_FLOPS side of
the roofline's "useful fraction" (MODEL_FLOPS / HLO_FLOPs) and the sanity
cross-check for the composed cost analysis.

Conventions (standard):
  train   : 6·N_active per token (fwd 2 + bwd 4) + attention 12·H·hd·S/2
            (+1 extra fwd of everything when remat is on → 8·N + ...)
  prefill : 2·N_active per token + attention 4·H·hd·S/2
  decode  : 2·N_active per token + attention 4·H·hd·S_ctx (full cache read)
"""

from __future__ import annotations

from typing import Dict

from ..configs.base import ModelConfig
from .specs import SHAPES


def _attn_ctx_flops_per_token(cfg: ModelConfig, s_ctx: float,
                              causal_avg: bool) -> float:
    """QK^T + PV flops per token for one layer at context s_ctx."""
    if cfg.attn_kind == "none":
        # rwkv: per-token state update ~ H·hs·hs MACs × (2 ops)
        hs = cfg.rwkv_head_size
        h = cfg.d_model // hs
        return 2 * 3 * h * hs * hs  # outer product + readout + decay
    eff = s_ctx / 2 if causal_avg else s_ctx
    qk_dim = cfg.head_dim if cfg.attn_kind != "mla" else \
        (cfg.qk_nope_dim + cfg.qk_rope_dim)
    v_dim = cfg.head_dim if cfg.attn_kind != "mla" else cfg.v_head_dim
    return 2 * cfg.num_heads * (qk_dim + v_dim) * eff


def _layer_kinds(cfg: ModelConfig):
    return list(cfg.block_pattern) * cfg.pattern_repeats + \
        list(cfg.remainder_layers)


def model_flops(cfg: ModelConfig, shape_name: str) -> Dict[str, float]:
    sh = SHAPES[shape_name]
    seq, batch = sh["seq"], sh["batch"]
    n_active = cfg.active_param_count()
    kinds = _layer_kinds(cfg)

    def attn_flops(s_ctx, causal_avg, tokens):
        per_layer = 0.0
        for kind in kinds:
            if kind in ("attn", "moe", "decoder"):
                per_layer += _attn_ctx_flops_per_token(cfg, s_ctx,
                                                       causal_avg)
            elif kind == "local_attn":
                w = min(cfg.window or s_ctx, s_ctx)
                per_layer += _attn_ctx_flops_per_token(cfg, w, causal_avg)
            elif kind == "cross_attn":
                per_layer += _attn_ctx_flops_per_token(cfg, cfg.img_seq,
                                                       False)
            elif kind == "rwkv":
                per_layer += _attn_ctx_flops_per_token(cfg, 0, False)
            elif kind == "recurrent":
                per_layer += 2 * 3 * cfg.lru_dim  # lru update per token
        return per_layer * tokens

    if sh["kind"] == "train":
        tokens = seq * batch
        tot_mult = 6.0               # fwd 2 + bwd 4 per active param
        if cfg.remat:
            tot_mult += 2.0          # remat replays the forward once
            if cfg.remat_group > 1:
                tot_mult += 2.0      # 2-level remat replays it twice
        return {"model_flops": 6.0 * n_active * tokens
                + 3 * attn_flops(seq, True, tokens),
                "compiled_expected": tot_mult * n_active * tokens
                + (tot_mult / 2.0) * attn_flops(seq, True, tokens),
                "tokens": float(tokens), "n_active": float(n_active)}
    if sh["kind"] == "prefill":
        tokens = seq * batch
        return {"model_flops": 2.0 * n_active * tokens
                + attn_flops(seq, True, tokens),
                "compiled_expected": 2.0 * n_active * tokens
                + attn_flops(seq, True, tokens),
                "tokens": float(tokens), "n_active": float(n_active)}
    # decode: one token per sequence against a seq-long context
    tokens = batch
    return {"model_flops": 2.0 * n_active * tokens
            + attn_flops(seq, False, tokens),
            "compiled_expected": 2.0 * n_active * tokens
            + attn_flops(seq, False, tokens),
            "tokens": float(tokens), "n_active": float(n_active)}


def prefill_attention_correction(cfg: ModelConfig, shape_name: str,
                                 q_chunk: int = 1024) -> float:
    """Per-DEVICE flops the composed HLO misses for prefill cells: the
    q-chunk attention scan body is counted once instead of nq times.
    Returns the additive correction (global / 256 chips)."""
    sh = SHAPES[shape_name]
    if sh["kind"] != "prefill" or sh["seq"] < 16384:
        return 0.0
    nq = sh["seq"] // q_chunk
    kinds = _layer_kinds(cfg)
    tokens = sh["seq"] * sh["batch"]
    per_layer = 0.0
    for kind in kinds:
        if kind in ("attn", "moe", "decoder"):
            per_layer += _attn_ctx_flops_per_token(cfg, sh["seq"], True)
        elif kind == "local_attn":
            per_layer += _attn_ctx_flops_per_token(
                cfg, min(cfg.window or sh["seq"], sh["seq"]), True)
    attn_total = per_layer * tokens
    return attn_total * (nq - 1) / nq / 256.0


def decode_hbm_bytes(cfg: ModelConfig, shape_name: str) -> float:
    """Decode is memory-bound: params (bf16... stored f32 here) + KV cache
    read once per step."""
    sh = SHAPES[shape_name]
    if sh["kind"] != "decode":
        return 0.0
    param_bytes = cfg.param_count() * 4.0
    kinds = _layer_kinds(cfg)
    cache = 0.0
    for kind in kinds:
        if kind in ("attn", "moe", "decoder"):
            if cfg.attn_kind == "mla":
                per_tok = (cfg.kv_lora_rank + cfg.qk_rope_dim) * 2
            else:
                per_tok = 2 * cfg.num_kv_heads * cfg.head_dim * 2
            cache += sh["batch"] * sh["seq"] * per_tok
        elif kind == "local_attn":
            cache += sh["batch"] * min(cfg.window or 0, sh["seq"]) \
                * 2 * cfg.num_kv_heads * cfg.head_dim * 2
        elif kind == "rwkv":
            hs = cfg.rwkv_head_size
            cache += sh["batch"] * (cfg.d_model // hs) * hs * hs * 4
        elif kind == "recurrent":
            cache += sh["batch"] * cfg.lru_dim * 4
    return param_bytes + cache
