"""Roofline analysis from the dry-run dumps (EXPERIMENTS.md §Roofline).

Terms per (arch × shape), single-pod 16×16 mesh, from the per-device
composed cost analysis (see dryrun.py for the while-body composition):

  compute_s    = HLO_FLOPs_per_device / 197e12        (bf16 peak / chip)
  memory_s     = HLO_bytes_per_device / 819e9         (HBM BW / chip)
  collective_s = collective_bytes_per_device / 50e9   (1 ICI link, worst
                 case serialization; v5e has 4 links → best case ÷4)

The dominant term is the bottleneck; roofline fraction for the dominant
term = useful/attained:  MODEL_FLOPS/(chips·peak·T_dom) when compute-
dominated, else term_ratio = T_dom / ΣT (how far overlap could help).

Usage: python -m repro.launch.roofline --in results/dryrun_single \
           [--md results/roofline.md]
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List, Optional

from ..configs.base import get_config
from . import analytic
from .specs import SHAPES

PEAK_FLOPS = 197e12     # bf16 / chip (TPU v5e class)
HBM_BW = 819e9          # bytes/s / chip
ICI_BW = 50e9           # bytes/s / link
CHIPS = 256


def kernel_roofline(flops: float, hbm_bytes: float,
                    ici_bytes: float = 0.0) -> Dict:
    """Single-chip roofline for one kernel invocation (no dry-run dump):
    seconds per term, the dominant bottleneck, and the modeled runtime
    assuming perfect compute/memory overlap.  The kernel autotuner
    (kernels/autotune.py) validates its *measured* winner against this
    model — agreement means the measurement is believable, disagreement
    is recorded (measured always wins; the model can't see interpret
    mode or VMEM effects)."""
    t_compute = flops / PEAK_FLOPS
    t_memory = hbm_bytes / HBM_BW
    t_coll = ici_bytes / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)
    return {"t_compute_s": t_compute, "t_memory_s": t_memory,
            "t_collective_s": t_coll, "dominant": dominant,
            "modeled_s": max(t_compute, t_memory) + t_coll}


def load_cells(directory: str) -> List[Dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(directory, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def roofline_row(cell: Dict) -> Optional[Dict]:
    if cell.get("status") != "ok":
        return None
    comp = cell.get("composed") or {"cost": cell["full"]["cost"],
                                    "collectives":
                                        cell["full"]["collectives"]}
    cfg0 = get_config(cell["arch"])
    flops_dev = comp["cost"]["flops"] \
        + analytic.prefill_attention_correction(cfg0, cell["shape"])
    bytes_dev = comp["cost"]["bytes"]
    coll_dev = comp["collectives"].get("total_bytes", 0.0)
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_dev / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)

    cfg = get_config(cell["arch"])
    an = analytic.model_flops(cfg, cell["shape"])
    hlo_total = flops_dev * CHIPS
    useful = an["model_flops"] / hlo_total if hlo_total else 0.0
    # attained fraction of the dominant roof if perfectly overlapped
    t_dom = terms[dominant]
    mfu_bound = an["model_flops"] / (CHIPS * PEAK_FLOPS * t_dom) \
        if t_dom else 0.0
    return {
        "arch": cell["arch"], "shape": cell["shape"],
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops": an["model_flops"], "hlo_flops_total": hlo_total,
        "useful_ratio": useful, "mfu_bound": mfu_bound,
        "peak_gib": cell["full"]["mem"]["peak_est_bytes"] / 2**30,
        "coll_bytes_dev": coll_dev,
        "collectives": {k: v for k, v in comp["collectives"].items()
                        if k not in ("total_bytes", "count")},
    }


def make_table(cells: List[Dict]) -> str:
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant "
        "| MODEL_FLOPS | useful (MF/HLO) | MFU bound | peak GiB |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    rows = []
    for c in cells:
        r = roofline_row(c)
        if r is None:
            lines.append(
                f"| {c['arch']} | {c['shape']} | — | — | — | "
                f"{c['status']}: {c.get('reason', c.get('error', ''))[:60]}"
                f" | | | | |")
            continue
        rows.append(r)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3e} | "
            f"{r['t_memory_s']:.3e} | {r['t_collective_s']:.3e} | "
            f"**{r['dominant']}** | {r['model_flops']:.2e} | "
            f"{r['useful_ratio']:.2f} | {r['mfu_bound']:.2f} | "
            f"{r['peak_gib']:.2f} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--in", dest="indir", default="results/dryrun_single")
    ap.add_argument("--md", default=None)
    ap.add_argument("--json", dest="json_out", default=None)
    args = ap.parse_args()
    cells = load_cells(args.indir)
    # order: arch registry order × shape order
    order = {s: i for i, s in enumerate(SHAPES)}
    cells.sort(key=lambda c: (c["arch"], order.get(c["shape"], 9)))
    table = make_table(cells)
    print(table)
    if args.md:
        with open(args.md, "w") as f:
            f.write("# Roofline (single-pod 16×16, per-step)\n\n")
            f.write(table + "\n")
    if args.json_out:
        rows = [r for r in (roofline_row(c) for c in cells) if r]
        with open(args.json_out, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
