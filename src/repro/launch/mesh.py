"""Production mesh builders.

Functions, not module-level constants — importing this module never
touches jax device state (smoke tests must keep seeing 1 CPU device)."""

from __future__ import annotations

import jax


def _mesh(shape, axes):
    # jax.sharding.AxisType landed after 0.4.x; Auto is the default there
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod (TPU v5e pod slice); 2 pods = 512 chips
    with a leading 'pod' axis for cross-pod data parallelism."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_factored_mesh(*, multi_pod: bool = False, factors=(8, 2)):
    """Same 256 chips/pod, but the model axis is FACTORED (model=8 ×
    model2=2): architectures whose head counts don't divide 16 (MiniCPM3:
    40 heads, Llama-4: 40) can shard heads over the 8-sub-axis while
    mlp/vocab still use all 16 — beyond-paper optimization, see
    EXPERIMENTS.md §Perf."""
    shape = (2, 16) + factors if multi_pod else (16,) + factors
    axes = ("pod", "data", "model", "model2") if multi_pod else \
        ("data", "model", "model2")
    return _mesh(shape, axes)


def make_host_mesh():
    """Whatever devices exist (tests: 1 CPU → (1,1))."""
    n = len(jax.devices())
    d = 1
    for cand in (16, 8, 4, 2, 1):
        if n % cand == 0 and n >= cand:
            d = cand
            break
    return _mesh((n // d, d), ("data", "model"))
