import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

This is the proof that the distribution config is coherent without real
hardware: 512 placeholder host devices stand in for 2 pods × 256 chips.
For every cell we:

  1. build abstract inputs (ShapeDtypeStruct + NamedSharding; nothing is
     allocated),
  2. jit-lower and compile the real entry point (train_step / prefill /
     decode_step),
  3. record memory_analysis (does it fit 16 GB/chip?), cost_analysis, and
     the collective schedule parsed from the post-SPMD HLO.

Cost composition: XLA's cost_analysis counts while-loop bodies ONCE
(verified empirically), so scanned-layer models would be undercounted by
~L×.  We therefore also compile the superblock *piece* (fwd and fwd+bwd)
separately and compose:   total = full + (reps−1)·piece (+ accum scaling
for the microbatch loop).  Residual error: collectives/flops inside the
recurrent time-chunk scans are still counted once per chunk-loop (≤ ~5%
of block flops for rwkv/griffin; noted in EXPERIMENTS.md).

Usage:
  python -m repro.launch.dryrun --arch granite-3-2b --shape train_4k
  python -m repro.launch.dryrun --all --out results/dryrun
  python -m repro.launch.dryrun --all --multi-pod
"""

import argparse
import dataclasses
import json
import re
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import ARCH_IDS, get_config, ModelConfig
from ..models import lm
from ..sharding.rules import parse_axes, spec_for, tree_spec
from ..train.optimizer import make_optimizer, warmup_cosine
from ..train.step import make_train_step
from . import specs as S
from .mesh import make_production_mesh

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
                "c64": 8, "c128": 16}

_COLL_RE = re.compile(
    r"=\s+(.+?)\s+(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")


def _shape_bytes(result_str: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(result_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> Dict[str, float]:
    """Per-device collective payload bytes by type (result shapes of every
    collective op in the post-SPMD module; loop bodies appear once)."""
    out: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        res, kind = m.group(1), m.group(2)
        out[kind] = out.get(kind, 0.0) + _shape_bytes(res)
        out["count"] = out.get("count", 0.0) + 1
    out["total_bytes"] = sum(v for k, v in out.items()
                             if k not in ("count", "total_bytes"))
    return out


def _cost(compiled) -> Dict[str, float]:
    c = compiled.cost_analysis() or {}
    if isinstance(c, (list, tuple)):  # jax 0.4.x: one dict per partition
        c = c[0] if c else {}
    return {"flops": float(c.get("flops", 0.0)),
            "bytes": float(c.get("bytes accessed", 0.0))}


def _mem(compiled) -> Dict[str, float]:
    m = compiled.memory_analysis()
    return {"argument_bytes": float(m.argument_size_in_bytes),
            "output_bytes": float(m.output_size_in_bytes),
            "temp_bytes": float(m.temp_size_in_bytes),
            "alias_bytes": float(m.alias_size_in_bytes),
            "peak_est_bytes": float(m.argument_size_in_bytes
                                    + m.output_size_in_bytes
                                    + m.temp_size_in_bytes
                                    - m.alias_size_in_bytes)}


def _compile(fn, args, donate=None, out_shardings=None):
    t0 = time.time()
    kw = {}
    if donate is not None:
        kw["donate_argnums"] = donate
    if out_shardings is not None:
        kw["out_shardings"] = out_shardings
    lowered = jax.jit(fn, **kw).lower(*args)
    compiled = lowered.compile()
    dt = time.time() - t0
    txt = compiled.as_text()
    return {"cost": _cost(compiled), "mem": _mem(compiled),
            "collectives": parse_collectives(txt), "compile_s": dt}


def _scale(d: Dict[str, float], k: float) -> Dict[str, float]:
    return {key: v * k for key, v in d.items()}


def _add(a: Dict[str, float], b: Dict[str, float]) -> Dict[str, float]:
    return {k: a.get(k, 0.0) + b.get(k, 0.0)
            for k in set(a) | set(b)}


def _strip_stack(axes_tree):
    return jax.tree.map(
        lambda s: " ".join(t for t in s.split() if t != "stack"), axes_tree)


def _sb_param_sds(cfg: ModelConfig, mesh, params_sds, axes):
    """Abstract ONE slice of the stacked superblock params."""
    blocks = params_sds["blocks"]
    baxes = _strip_stack(axes["blocks"])
    shapes = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape[1:], s.dtype), blocks)
    sp = tree_spec(shapes, baxes, mesh)
    return jax.tree.map(
        lambda s, p: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, p)),
        shapes, sp)


def _sb_cache_sds(cfg: ModelConfig, mesh, cache_sds):
    blocks = cache_sds["blocks"]
    baxes = _strip_stack({"blocks": lm.cache_axes(cfg)["blocks"]})["blocks"]
    shapes = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape[1:], s.dtype), blocks)
    sp = tree_spec(shapes, baxes, mesh)
    return jax.tree.map(
        lambda s, p: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, p)),
        shapes, sp)


def _x_sds(cfg, mesh, batch, seq):
    return jax.ShapeDtypeStruct(
        (batch, seq, cfg.d_model), jnp.bfloat16,
        sharding=NamedSharding(mesh, spec_for((batch, seq, cfg.d_model),
                                              "batch seq .", mesh)))


def _enc_sds(cfg, mesh, batch):
    if cfg.img_seq:
        n = cfg.img_seq
    elif cfg.encdec:
        n = cfg.encoder_seq
    else:
        return None
    return jax.ShapeDtypeStruct(
        (batch, n, cfg.d_model), jnp.bfloat16,
        sharding=NamedSharding(mesh, spec_for((batch, n, cfg.d_model),
                                              "batch . .", mesh)))


def _sb_fwd_fn(cfg: ModelConfig, with_enc: bool):
    pat = cfg.block_pattern

    def f(ps, x, enc=None):
        b, s, _ = x.shape
        positions = jnp.broadcast_to(
            jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        aux = jnp.float32(0.0)
        for j, kind in enumerate(pat):
            x, a_ = lm.block_apply_train(cfg, kind, ps[f"b{j}"], x,
                                         positions=positions, enc=enc)
            aux = aux + a_
        return x, aux

    if with_enc:
        return f
    return lambda ps, x: f(ps, x, None)


# ---------------------------------------------------------------------------
# cell runners
# ---------------------------------------------------------------------------


def run_train_cell(cfg: ModelConfig, mesh, pieces: bool = True,
                   shard_grads: bool = True) -> Dict[str, Any]:
    accum = S.accum_for(cfg.name, mesh)
    sh = S.SHAPES["train_4k"]
    opt = make_optimizer(cfg.optimizer, warmup_cosine(3e-4, 100, 10000))
    params_sds, axes = S.abstract_params(cfg, mesh)
    opt_sds = S.abstract_opt_state(opt, params_sds, axes, mesh)
    batch_sds = S.batch_specs(cfg, mesh, sh["batch"], sh["seq"], train=True)

    p_sh = jax.tree.map(lambda s: s.sharding, params_sds)
    # NOTE: also tried pinning per-layer grad shardings via in-scan-body
    # param constraints (with_sharding_constraint is its own transpose) —
    # no measurable change; the per-layer reduce is placed by GSPMD inside
    # the backward layer scan either way (EXPERIMENTS.md §Perf, dbrx it.2)
    sb_sh = None
    ts = make_train_step(cfg, opt, accum_steps=accum,
                         grad_shardings=p_sh if shard_grads else None,
                         sb_param_shardings=sb_sh)
    o_sh = jax.tree.map(lambda s: s.sharding, opt_sds)
    metrics_shape = jax.eval_shape(ts, params_sds, opt_sds, batch_sds)[2]
    m_sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), metrics_shape)

    out: Dict[str, Any] = {"accum_steps": accum}
    with mesh:
        full = _compile(ts, (params_sds, opt_sds, batch_sds),
                        donate=(0, 1), out_shardings=(p_sh, o_sh, m_sh))
    out["full"] = full

    if not pieces:
        return out

    # --- composition pieces (single-pod roofline only) ---
    mb = sh["batch"] // accum
    with mesh:
        # (1) microbatch grad, accum=1 (tail + one superblock in cost)
        ts1 = make_train_step(cfg, opt, accum_steps=1,
                              grad_shardings=p_sh if shard_grads else None,
                              sb_param_shardings=sb_sh)
        mb_batch = S.batch_specs(cfg, mesh, mb, sh["seq"], train=True)
        mb_grad = _compile(ts1, (params_sds, opt_sds, mb_batch),
                           donate=(0, 1), out_shardings=(p_sh, o_sh, m_sh))
        out["mb_step"] = mb_grad

        # (2) superblock fwd and fwd+bwd pieces
        sb_sds = _sb_param_sds(cfg, mesh, params_sds, axes)
        x_sds = _x_sds(cfg, mesh, mb, sh["seq"])
        enc_sds = _enc_sds(cfg, mesh, mb)
        fwd = _sb_fwd_fn(cfg, enc_sds is not None)
        args = (sb_sds, x_sds) + ((enc_sds,) if enc_sds is not None else ())
        out["sb_fwd"] = _compile(fwd, args)

        def vjp_fn(*a):
            ct_x = a[-1]
            ins = a[:-1]
            y, pull = jax.vjp(fwd, *ins)
            return pull((ct_x, jnp.float32(1.0)))
        out["sb_vjp"] = _compile(vjp_fn, args + (x_sds,))

    reps = cfg.pattern_repeats
    rg = cfg.remat_group if (cfg.remat_group > 1
                             and reps % cfg.remat_group == 0) else 1
    # composed per-step cost: accum×(mb_step + (reps−rg)×(sb_fwd+sb_vjp))
    # — the full lowering's scan body already contains rg superblocks.
    sbc = _add(out["sb_fwd"]["cost"], out["sb_vjp"]["cost"])
    sbcoll = _add(out["sb_fwd"]["collectives"],
                  out["sb_vjp"]["collectives"])
    comp_cost = _scale(_add(out["mb_step"]["cost"],
                            _scale(sbc, reps - rg)), accum)
    comp_coll = _scale(_add(out["mb_step"]["collectives"],
                            _scale(sbcoll, reps - rg)), accum)
    out["composed"] = {"cost": comp_cost, "collectives": comp_coll,
                       "note": "optimizer counted accum× (≤ few % over)"}
    return out


def run_prefill_cell(cfg: ModelConfig, mesh, pieces: bool = True
                     ) -> Dict[str, Any]:
    sh = S.SHAPES["prefill_32k"]
    params_sds, axes = S.abstract_params(cfg, mesh)
    batch_sds = S.batch_specs(cfg, mesh, sh["batch"], sh["seq"],
                              train=False)

    def pf(p, batch):
        return lm.prefill(cfg, p, batch, cache_len=sh["seq"])

    out: Dict[str, Any] = {}
    with mesh:
        out["full"] = _compile(pf, (params_sds, batch_sds))
    if not pieces:
        return out

    with mesh:
        sb_sds = _sb_param_sds(cfg, mesh, params_sds, axes)
        x_sds = _x_sds(cfg, mesh, sh["batch"], sh["seq"])
        enc_sds = _enc_sds(cfg, mesh, sh["batch"])
        pat = cfg.block_pattern

        def sb_pf(ps, x, enc=None):
            b, s_ = x.shape[:2]
            positions = jnp.broadcast_to(
                jnp.arange(s_, dtype=jnp.int32)[None], (b, s_))
            caches = []
            for j, kind in enumerate(pat):
                x, c = lm.block_prefill(cfg, kind, ps[f"b{j}"], x,
                                        positions=positions,
                                        cache_len=sh["seq"], enc=enc)
                caches.append(c)
            return x, caches

        f = sb_pf if enc_sds is not None else (
            lambda ps, x: sb_pf(ps, x, None))
        args = (sb_sds, x_sds) + ((enc_sds,) if enc_sds is not None else ())
        out["sb"] = _compile(f, args)

    reps = cfg.pattern_repeats
    out["composed"] = {
        "cost": _add(out["full"]["cost"],
                     _scale(out["sb"]["cost"], reps - 1)),
        "collectives": _add(out["full"]["collectives"],
                            _scale(out["sb"]["collectives"], reps - 1))}
    return out


def run_decode_cell(cfg: ModelConfig, mesh, shape_name: str,
                    pieces: bool = True) -> Dict[str, Any]:
    sh = S.SHAPES[shape_name]
    params_sds, axes = S.abstract_params(cfg, mesh)
    cache_sds = S.cache_specs(cfg, mesh, sh["batch"], sh["seq"])
    tok_sds, pos_sds = S.decode_input_specs(cfg, mesh, sh["batch"])
    c_sh = jax.tree.map(lambda s: s.sharding, cache_sds)

    def step(p, c, t, pos):
        return lm.decode_step(cfg, p, c, t, pos)

    out: Dict[str, Any] = {}
    with mesh:
        out["full"] = _compile(step,
                               (params_sds, cache_sds, tok_sds, pos_sds),
                               donate=(1,),
                               out_shardings=(None, c_sh))
    if not pieces:
        return out

    with mesh:
        sb_sds = _sb_param_sds(cfg, mesh, params_sds, axes)
        sbc_sds = _sb_cache_sds(cfg, mesh, cache_sds)
        x_sds = _x_sds(cfg, mesh, sh["batch"], 1)
        pat = cfg.block_pattern

        def sb_dec(ps, cs, x, pos):
            new = []
            for j, kind in enumerate(pat):
                x, c = lm.block_decode(cfg, kind, ps[f"b{j}"], x,
                                       cs[f"b{j}"], pos=pos)
                new.append(c)
            return x, new

        out["sb"] = _compile(sb_dec, (sb_sds, sbc_sds, x_sds, pos_sds))

    reps = cfg.pattern_repeats
    out["composed"] = {
        "cost": _add(out["full"]["cost"],
                     _scale(out["sb"]["cost"], reps - 1)),
        "collectives": _add(out["full"]["collectives"],
                            _scale(out["sb"]["collectives"], reps - 1))}
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool = False,
             pieces: bool = True, factored: bool = False,
             shard_grads: bool = True) -> Dict[str, Any]:
    cfg = get_config(arch)
    ok, why = S.cell_applicable(cfg, shape_name)
    base = {"arch": arch, "shape": shape_name,
            "mesh": ("2x16x16" if multi_pod else "16x16")
            + ("f" if factored else "")}
    if not ok:
        return dict(base, status="skipped", reason=why)
    if factored:
        from .mesh import make_factored_mesh
        mesh = make_factored_mesh(multi_pod=multi_pod)
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    pieces = pieces and not multi_pod  # roofline is single-pod only
    t0 = time.time()
    try:
        if shape_name == "train_4k":
            r = run_train_cell(cfg, mesh, pieces, shard_grads=shard_grads)
        elif shape_name == "prefill_32k":
            r = run_prefill_cell(cfg, mesh, pieces)
        else:
            r = run_decode_cell(cfg, mesh, shape_name, pieces)
        return dict(base, status="ok", wall_s=time.time() - t0, **r)
    except Exception as e:  # a failure here is a bug in our sharding
        return dict(base, status="error", error=f"{type(e).__name__}: {e}",
                    traceback=traceback.format_exc()[-2000:],
                    wall_s=time.time() - t0)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(S.SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-pieces", action="store_true")
    ap.add_argument("--factored", action="store_true",
                    help="factored model axis (16,8,2) — §Perf variant")
    ap.add_argument("--no-shard-grads", action="store_true",
                    help="disable grad reduce-scatter pinning (baseline)")
    ap.add_argument("--out", default=None, help="directory for JSON dumps")
    args = ap.parse_args()

    cells = []
    if args.all:
        for a in ARCH_IDS:
            for s_ in S.SHAPES:
                cells.append((a, s_))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    results = []
    for a, s_ in cells:
        r = run_cell(a, s_, multi_pod=args.multi_pod,
                     pieces=not args.no_pieces, factored=args.factored,
                     shard_grads=not args.no_shard_grads)
        results.append(r)
        status = r["status"]
        extra = ""
        if status == "ok":
            peak = r["full"]["mem"]["peak_est_bytes"] / 2**30
            extra = f"peak={peak:.2f}GiB compile={r['full']['compile_s']:.1f}s"
        elif status == "error":
            extra = r["error"][:160]
        else:
            extra = r["reason"][:80]
        print(f"[{r['mesh']}] {a:28s} {s_:12s} {status:8s} {extra}",
              flush=True)
        if args.out:
            os.makedirs(args.out, exist_ok=True)
            tag = f"{a}__{s_}__{r['mesh'].replace('x','_')}.json"
            with open(os.path.join(args.out, tag), "w") as f:
                json.dump(r, f, indent=1)
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"\n== dry-run summary: {n_ok} ok, {n_skip} skipped "
          f"(documented), {n_err} errors ==")
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())


_ = (dataclasses, np, parse_axes, Optional)
