"""End-to-end training driver.

On this CPU container it trains the *reduced* config of any arch (the
full configs are dry-run-only); on a real pod slice the same entry point
runs the full config on the production mesh.

  PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
      --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import json

from ..configs.base import ARCH_IDS, get_config
from ..train.loop import TrainArgs, train, train_local_sgd, \
    train_with_restarts


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="granite-3-2b")
    ap.add_argument("--full", action="store_true",
                    help="full config (needs a real pod; default reduced)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a failure (recovered via restart)")
    ap.add_argument("--local-sgd", type=int, default=0,
                    help="worker count for the async local-SGD outer loop")
    ap.add_argument("--sync-period", type=int, default=10)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    targs = TrainArgs(steps=args.steps, batch_size=args.batch,
                      seq_len=args.seq, lr=args.lr,
                      accum_steps=args.accum, ckpt_dir=args.ckpt_dir,
                      ckpt_every=args.ckpt_every,
                      fail_at_step=args.fail_at)
    if args.local_sgd:
        out = train_local_sgd(cfg, targs, workers=args.local_sgd,
                              sync_period=args.sync_period)
    elif args.fail_at is not None:
        out = train_with_restarts(cfg, targs)
    else:
        out = train(cfg, targs, hooks={"on_log": lambda m: print(
            f"step {m['step']:5d}  loss {m['loss']:.4f}  "
            f"ppl {m.get('ppl', 0):.1f}  {m['wall_s']:.1f}s")})
    hist = out["history"]
    print(f"final loss: {hist[-1]['loss']:.4f} "
          f"(from {hist[0]['loss']:.4f})")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(hist, f, indent=1)


if __name__ == "__main__":
    main()
