"""Abstract input/parameter specs for AOT lowering (no allocation).

``input_specs`` provides ShapeDtypeStruct stand-ins for every model input
of every (arch × shape) cell, sharding-annotated for the given mesh —
the only way the FULL configs (up to 400B params) are ever touched.

Assigned shape cells (LM family):
  train_4k     seq 4096   global_batch 256   → train_step
  prefill_32k  seq 32768  global_batch 32    → prefill
  decode_32k   seq 32768  global_batch 128   → decode_step (1 new token)
  long_500k    seq 524288 global_batch 1     → decode_step, sub-quadratic
                archs only (rwkv6 / recurrentgemma); skips are recorded.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding

from ..configs.base import ModelConfig
from ..models import lm
from ..sharding.rules import spec_for, tree_spec

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}

# gradient-accumulation factor per arch for train_4k — sized so saved
# layer-input activations fit 16 GB/chip HBM next to params+grads+opt
# (napkin math in DESIGN.md §8; validated by dry-run memory_analysis)
ACCUM = {
    "dbrx-132b": 8,
    "llama4-maverick-400b-a17b": 16,
    "granite-3-2b": 4,
    "chatglm3-6b": 4,
    "minicpm3-4b": 8,
    "nemotron-4-340b": 16,   # + shard_seq_boundary (SP) for activations
    "rwkv6-1.6b": 8,
    "llama-3.2-vision-11b": 8,
    "whisper-tiny": 16,      # unshardable 51865-vocab logits dominate
    "recurrentgemma-9b": 8,
}


def accum_for(arch: str, mesh) -> int:
    """Cap accumulation so the microbatch stays divisible by the batch
    sharding extent (pod×data) — an unshardable microbatch would silently
    replicate activations on every data shard."""
    batch_shards = mesh.shape.get("pod", 1) * mesh.shape.get("data", 1)
    cap = max(1, SHAPES["train_4k"]["batch"] // batch_shards)
    return min(ACCUM[arch], cap)


def cell_applicable(cfg: ModelConfig, shape_name: str) -> Tuple[bool, str]:
    if shape_name == "long_500k" and not cfg.supports_long_context:
        return False, ("full quadratic attention at 524288 context is "
                       "intractable; arch has no sub-quadratic path "
                       "(noted in DESIGN.md §Arch-applicability)")
    if shape_name.startswith("decode") or shape_name == "long_500k":
        if not cfg.decoder:
            return False, "encoder-only arch has no decode step"
    return True, ""


def axes_probe(cfg: ModelConfig) -> ModelConfig:
    """Tiny-dims config with IDENTICAL pytree structure to the full one —
    used to materialize the logical-axes pytree cheaply (axes strings are
    structure, not math)."""
    return dataclasses.replace(
        cfg.reduced(), name=cfg.name + "-axesprobe",
        num_layers=cfg.num_layers,
        encoder_layers=cfg.encoder_layers)


def param_axes(cfg: ModelConfig):
    _, axes = lm.init(axes_probe(cfg), jax.random.PRNGKey(0))
    return axes


def abstract_params(cfg: ModelConfig, mesh: Mesh):
    """(ShapeDtypeStruct pytree with shardings, axes pytree)."""
    shapes = jax.eval_shape(lambda k: lm.init(cfg, k)[0],
                            jax.random.PRNGKey(0))
    axes = param_axes(cfg)
    specs = tree_spec(shapes, axes, mesh)
    sds = jax.tree.map(
        lambda s, sp: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, sp)),
        shapes, specs)
    return sds, axes


def abstract_opt_state(optimizer, params_sds, axes, mesh: Mesh):
    shapes = jax.eval_shape(optimizer.init, params_sds)
    st_axes = optimizer.state_axes(axes)
    specs = tree_spec(shapes, st_axes, mesh)
    return jax.tree.map(
        lambda s, sp: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, sp)),
        shapes, specs)


def _sds(shape, dtype, mesh, axes_str):
    sp = spec_for(shape, axes_str, mesh)
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, sp))


def batch_specs(cfg: ModelConfig, mesh: Mesh, batch: int, seq: int,
                train: bool) -> Dict[str, jax.ShapeDtypeStruct]:
    out = {"tokens": _sds((batch, seq), jnp.int32, mesh, "batch seq")}
    if train:
        out["labels"] = _sds((batch, seq), jnp.int32, mesh, "batch seq")
        out["loss_mask"] = _sds((batch, seq), jnp.float32, mesh,
                                "batch seq")
    if cfg.img_seq:
        out["img_embeds"] = _sds((batch, cfg.img_seq, cfg.d_model),
                                 jnp.bfloat16, mesh, "batch img_seq .")
    if cfg.encdec:
        out["enc_embeds"] = _sds((batch, cfg.encoder_seq, cfg.d_model),
                                 jnp.bfloat16, mesh, "batch enc_seq .")
    return out


def cache_specs(cfg: ModelConfig, mesh: Mesh, batch: int, cache_len: int):
    shapes = jax.eval_shape(
        lambda: lm.init_cache(cfg, batch, cache_len, jnp.bfloat16))
    axes = lm.cache_axes(cfg)
    specs = tree_spec(shapes, axes, mesh)
    return jax.tree.map(
        lambda s, sp: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, sp)),
        shapes, specs)


def decode_input_specs(cfg: ModelConfig, mesh: Mesh, batch: int):
    tok = _sds((batch,), jnp.int32, mesh, "batch")
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return tok, pos


def input_specs(cfg: ModelConfig, mesh: Mesh, shape_name: str):
    """All abstract inputs for one (arch × shape) cell."""
    sh = SHAPES[shape_name]
    if sh["kind"] == "train":
        return {"batch": batch_specs(cfg, mesh, sh["batch"], sh["seq"],
                                     train=True)}
    if sh["kind"] == "prefill":
        return {"batch": batch_specs(cfg, mesh, sh["batch"], sh["seq"],
                                     train=False)}
    # decode: cache at full context + one token
    tok, pos = decode_input_specs(cfg, mesh, sh["batch"])
    return {"cache": cache_specs(cfg, mesh, sh["batch"], sh["seq"]),
            "token": tok, "pos": pos}


_ = (np, Optional)
