"""Serving driver: batched generation / continuous-batching demo on the
reduced config (full configs are dry-run-only on CPU)."""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs.base import ARCH_IDS, get_config
from ..models import lm
from ..serve.engine import Request, ServeLoop, generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="granite-3-2b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--mode", choices=["static", "continuous"],
                    default="continuous")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    extras = {}
    if cfg.img_seq:
        extras["img_embeds"] = np.zeros(
            (args.requests, cfg.img_seq, cfg.d_model), np.float32)
    if cfg.encdec:
        extras["enc_embeds"] = np.zeros(
            (args.requests, cfg.encoder_seq, cfg.d_model), np.float32)

    t0 = time.time()
    if args.mode == "static":
        prompts = rng.integers(2, cfg.vocab_size,
                               (args.requests, args.prompt_len))
        toks = generate(cfg, params, prompts.astype(np.int32),
                        max_new_tokens=args.max_new,
                        extras={k: v for k, v in extras.items()})
        print(f"generated {toks.shape} in {time.time()-t0:.1f}s")
    else:
        def exf(n):
            out = {}
            if cfg.img_seq:
                out["img_embeds"] = np.zeros((n, cfg.img_seq, cfg.d_model),
                                             np.float32)
            if cfg.encdec:
                out["enc_embeds"] = np.zeros(
                    (n, cfg.encoder_seq, cfg.d_model), np.float32)
            return out
        sl = ServeLoop(cfg, params, num_slots=args.slots,
                       cache_len=args.prompt_len + args.max_new + 8,
                       extras_fn=exf)
        reqs = [Request(rid=i,
                        prompt=rng.integers(
                            2, cfg.vocab_size,
                            args.prompt_len).astype(np.int32),
                        max_new=args.max_new)
                for i in range(args.requests)]
        for r in reqs:
            sl.submit(r)
        steps = sl.run()
        done = sum(r.done for r in reqs)
        tput = sum(len(r.generated) for r in reqs) / (time.time() - t0)
        print(f"{done}/{len(reqs)} requests in {steps} decode steps; "
              f"{tput:.1f} tok/s ({args.slots} slots)")


if __name__ == "__main__":
    main()
