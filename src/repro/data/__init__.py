from .pipeline import SyntheticCorpus, make_iterator  # noqa: F401
