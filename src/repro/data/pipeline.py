"""Deterministic synthetic data pipeline with document packing.

Offline container ⇒ no real corpora; the generator produces a *learnable*
language: a hidden token-transition permutation with zipf-distributed
"noise" tokens and documents of random length packed into fixed windows
with EOS separators (GPT-style packing).  A small model's loss drops
quickly on it, which is what the end-to-end example/test verifies.

Determinism & distribution: batch ``i`` of shard ``h`` depends only on
(seed, i, h) — restart-safe (the loop resumes at the saved step index) and
host-shardable (each data-parallel host pulls its own shard), matching a
1000-node deployment where every host computes its slice of the global
batch independently.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass
class SyntheticCorpus:
    vocab_size: int
    seed: int = 0
    eos: int = 1
    structure: float = 0.85      # P(next = perm[cur]) — learnability
    mean_doc_len: int = 192

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self.perm = rng.permutation(self.vocab_size)
        # zipf weights for the noise distribution
        ranks = np.arange(2, self.vocab_size + 2)
        w = 1.0 / ranks
        self.zipf_p = w / w.sum()

    def _doc(self, rng: np.random.Generator, max_len: int) -> np.ndarray:
        n = int(np.clip(rng.geometric(1.0 / self.mean_doc_len), 8, max_len))
        out = np.empty(n, dtype=np.int32)
        out[0] = rng.integers(2, self.vocab_size)
        structured = rng.random(n) < self.structure
        noise = rng.choice(self.vocab_size, size=n, p=self.zipf_p)
        for i in range(1, n):
            out[i] = self.perm[out[i - 1]] if structured[i] \
                else max(int(noise[i]), 2)
        out[-1] = self.eos
        return out

    def batch(self, index: int, batch_size: int, seq_len: int,
              shard: int = 0, num_shards: int = 1) -> Dict[str, np.ndarray]:
        """Deterministic function of (seed, index, shard)."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, index, shard, num_shards]))
        need = batch_size * (seq_len + 1)
        stream = []
        total = 0
        while total < need:
            d = self._doc(rng, seq_len)
            stream.append(d)
            total += len(d)
        flat = np.concatenate(stream)[:need].reshape(batch_size,
                                                     seq_len + 1)
        return {"tokens": flat[:, :-1].astype(np.int32),
                "labels": flat[:, 1:].astype(np.int32),
                "loss_mask": np.ones((batch_size, seq_len), np.float32)}


def make_iterator(corpus: SyntheticCorpus, batch_size: int, seq_len: int,
                  start_step: int = 0, shard: int = 0, num_shards: int = 1,
                  extras: Optional[Dict] = None
                  ) -> Iterator[Dict[str, np.ndarray]]:
    """Infinite iterator; ``start_step`` resumes mid-stream after restart."""
    i = start_step
    while True:
        b = corpus.batch(i, batch_size, seq_len, shard, num_shards)
        if extras:
            b = dict(b, **{k: f(i) for k, f in extras.items()})
        yield b
        i += 1
