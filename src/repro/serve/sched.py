"""Wave scheduler — continuous batching for the graph front door.

The paper's asynchronous thesis applied to *serving*: a self-timed
element fires when its inputs are ready, not on a global clock.
``GraphService.gather`` is the bulk-synchronous version of batching —
only requests one caller queued before its barrier share a wave.
``WaveScheduler`` is the self-timed version: a background thread watches
the request stream from *all* clients, groups requests that resolve to
the same plan (``GraphService.wave_key``), and closes a wave the moment
it is worth dispatching — when a group reaches ``max_wave`` sources, or
when its oldest request has waited ``max_wait_s`` (the classic
continuous-batching policy of LLM serving engines; ``serve.engine.
ServeLoop`` plays the same game with decode slots).

Execution goes through ``GraphService._run_wave`` — the exact code path
``gather`` uses — so scheduled results are bit-identical to direct
``GraphService.run`` calls.  Requests carry an optional *deadline*; a
request that expires while queued resolves to ``DeadlineExceeded``
instead of occupying a row in a wave somebody else is waiting on.
``Future.cancel()`` before the wave closes is honored the same way: the
request is purged from its pending group at wave-close time and never
occupies a wave row (``stats()["cancelled"]`` counts them).
"""

from __future__ import annotations

import collections
import dataclasses
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Dict, List, Optional, Tuple

from ..core.api import QuerySpec
from .graph import GraphService, _Pending


class DeadlineExceeded(TimeoutError):
    """The request's deadline passed before a wave could serve it."""


class Backpressure(RuntimeError):
    """Admission control rejected a submit; ``stats`` says why (queue
    depth, plan-store thrash) so clients can back off intelligently."""

    def __init__(self, msg: str, stats: Optional[dict] = None):
        super().__init__(msg)
        self.stats = stats or {}


@dataclasses.dataclass(frozen=True)
class WavePolicy:
    """Scheduler knobs (one frozen object, like ``ExecutionPolicy``).

    max_wave:    close a wave as soon as a plan-group holds this many
                 requests (rides on top of ``GraphService.max_wave``,
                 which re-chunks oversized groups defensively).
    max_wait_s:  close a wave when its oldest request has waited this
                 long, full or not — the latency half of the
                 continuous-batching trade.
    max_pending: admission control — submits beyond this many queued
                 requests are rejected with ``Backpressure``.
    workers:     dispatch threads.  1 (default) serializes waves (plan
                 builds never race); >1 lets waves for different plans
                 overlap.
    thrash_evictions / thrash_window_s:  reject submits while the shared
                 ``PlanStore`` evicted ≥ this many plans inside the
                 window — batching on top of a store that is re-building
                 plans per query only amplifies the thrash.
    """

    max_wave: int = 64
    max_wait_s: float = 0.005
    max_pending: int = 1024
    workers: int = 1
    thrash_evictions: int = 64
    thrash_window_s: float = 1.0

    def __post_init__(self):
        if self.max_wave < 1:
            raise ValueError(f"max_wave must be >= 1: {self.max_wave!r}")
        if self.max_wait_s < 0:
            raise ValueError(
                f"max_wait_s must be >= 0: {self.max_wait_s!r}")
        if self.workers < 1:
            raise ValueError(f"workers must be >= 1: {self.workers!r}")

    def but(self, **kw) -> "WavePolicy":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass
class _Request:
    """One in-flight submit: a ``_Pending`` plus its future/deadline."""

    ticket: int
    name: str
    spec: QuerySpec
    key: Optional[tuple]            # GraphService.wave_key, None=solo
    future: Future
    t_submit: float                 # monotonic
    t_deadline: Optional[float]     # monotonic, None = no deadline


class WaveScheduler:
    """Background continuous-batching loop over a ``GraphService``.

    ``offer`` enqueues requests (thread-safe, any number of client
    threads); the scheduler thread closes waves per ``WavePolicy`` and
    dispatches them through ``GraphService._run_wave`` on a small worker
    pool, resolving each request's ``Future``.  Not started until
    ``start()`` — a paused scheduler just accumulates requests, which is
    also what makes batching deterministic for tests and benchmarks.
    """

    def __init__(self, service: GraphService, policy: WavePolicy):
        self.service = service
        self.policy = policy
        self._cv = threading.Condition()
        self._groups: "collections.OrderedDict[tuple, " \
            "collections.deque[_Request]]" = collections.OrderedDict()
        self._singles: "collections.deque[_Request]" = collections.deque()
        self._pending = 0
        self._inflight = 0
        self._running = False
        self._thread: Optional[threading.Thread] = None
        self._pool = ThreadPoolExecutor(max_workers=policy.workers,
                                        thread_name_prefix="repro-wave")
        self._stats = dict(waves=0, wave_queries=0, coalesced_waves=0,
                           max_wave=0, expired=0, cancelled=0,
                           completed=0, failed=0)

    # -- client side -----------------------------------------------------

    def offer(self, req: _Request) -> None:
        with self._cv:
            if req.key is not None:
                self._groups.setdefault(
                    req.key, collections.deque()).append(req)
            else:
                self._singles.append(req)
            self._pending += 1
            self._cv.notify_all()

    def pending(self) -> int:
        with self._cv:
            return self._pending

    def evict(self, name: str) -> int:
        """Resolve every queued request for ``name`` with ``KeyError``
        (mirrors ``GraphService.evict``'s promise that pending tickets
        are never silently dropped).  Returns how many were resolved."""
        err = KeyError(f"graph {name!r} was evicted before the query "
                       "ran")
        with self._cv:
            victims: List[_Request] = []
            for key in list(self._groups):
                dq = self._groups[key]
                keep = collections.deque(
                    r for r in dq if r.name != name)
                victims += [r for r in dq if r.name == name]
                if keep:
                    self._groups[key] = keep
                else:
                    del self._groups[key]
            keep = collections.deque(
                r for r in self._singles if r.name != name)
            victims += [r for r in self._singles if r.name == name]
            self._singles = keep
            self._pending -= len(victims)
            self._cv.notify_all()
        for r in victims:
            if r.future.set_running_or_notify_cancel():
                r.future.set_exception(err)
        return len(victims)

    # -- lifecycle -------------------------------------------------------

    def start(self) -> None:
        with self._cv:
            if self._running:
                return
            self._running = True
            self._thread = threading.Thread(target=self._loop,
                                            name="repro-wave-sched",
                                            daemon=True)
            self._thread.start()

    def stop(self, drain: bool = True, timeout: Optional[float] = None
             ) -> None:
        """Stop the loop.  ``drain=True`` (default) dispatches every
        queued request first — full wave or not; ``drain=False`` fails
        the queue with ``Backpressure`` (a shutting-down server is the
        ultimate admission refusal)."""
        with self._cv:
            self._running = False
            self._cv.notify_all()
            thread, self._thread = self._thread, None
        if thread is not None:
            thread.join(timeout)
        if drain:
            for key, wave in self._close_waves(force=True):
                self._dispatch(key, wave)
        else:
            err = Backpressure("scheduler stopped", self.stats())
            for _, wave in self._close_waves(force=True):
                for r in wave:
                    if r.future.set_running_or_notify_cancel():
                        r.future.set_exception(err)
                with self._cv:
                    self._inflight -= 1
                    self._cv.notify_all()
        self._pool.shutdown(wait=True)

    def drain(self, timeout: Optional[float] = None) -> bool:
        """Block until the queue AND in-flight waves are empty (or
        ``timeout``); True if fully drained."""
        end = None if timeout is None else time.monotonic() + timeout
        with self._cv:
            while self._pending or self._inflight:
                left = None if end is None else end - time.monotonic()
                if left is not None and left <= 0:
                    return False
                self._cv.wait(timeout=left)
        return True

    # -- the scheduling loop ---------------------------------------------

    def _loop(self) -> None:
        while True:
            with self._cv:
                if not self._running:
                    return   # stop() owns the final flush
                now = time.monotonic()
                due = self._next_event()
                if due is None or due > now:
                    wait = None if due is None else max(due - now, 1e-4)
                    self._cv.wait(timeout=wait)
                    if not self._running:
                        return
            for key, wave in self._close_waves(force=False):
                self._pool.submit(self._dispatch, key, wave)

    def _next_event(self) -> Optional[float]:
        """Earliest moment anything becomes actionable (caller holds
        ``_cv``): a single to run, a group's max-wait expiry, a full
        group (already due), or a request deadline."""
        now = time.monotonic()
        due: Optional[float] = None

        def upd(t: float):
            nonlocal due
            due = t if due is None else min(due, t)

        if self._singles:
            upd(now)
        for dq in self._groups.values():
            if len(dq) >= self.policy.max_wave:
                upd(now)
            elif dq:
                upd(dq[0].t_submit + self.policy.max_wait_s)
        for dq in list(self._groups.values()) + [self._singles]:
            for r in dq:
                if r.t_deadline is not None:
                    upd(r.t_deadline)
        return due

    def _close_waves(self, force: bool
                     ) -> List[Tuple[Optional[tuple], List[_Request]]]:
        """Pop every wave that is ready (full / waited out / forced),
        expiring dead-on-arrival requests first so they never occupy a
        row.  Returns [(wave_key or None, requests)]."""
        expired: List[_Request] = []
        todo: List[Tuple[Optional[tuple], List[_Request]]] = []
        now = time.monotonic()
        with self._cv:
            ncancel = self._purge_cancelled(self._singles)
            for dq in self._groups.values():
                ncancel += self._purge_cancelled(dq)
            self._expire(self._singles, now, expired)
            if self._singles:
                wave = list(self._singles)
                self._singles.clear()
                self._pending -= len(wave)
                self._inflight += 1
                todo.append((None, wave))
            for key in list(self._groups):
                dq = self._groups[key]
                self._expire(dq, now, expired)
                while dq and (force or len(dq) >= self.policy.max_wave
                              or now - dq[0].t_submit
                              >= self.policy.max_wait_s):
                    wave = [dq.popleft() for _ in
                            range(min(len(dq), self.policy.max_wave))]
                    self._pending -= len(wave)
                    self._inflight += 1
                    todo.append((key, wave))
                if not dq:
                    del self._groups[key]
            self._stats["expired"] += len(expired)
            if expired or ncancel:
                self._cv.notify_all()
        for r in expired:
            if r.future.set_running_or_notify_cancel():
                r.future.set_exception(DeadlineExceeded(
                    f"deadline exceeded after "
                    f"{now - r.t_submit:.3f}s in queue "
                    f"({r.spec.algo} on {r.name!r})"))
        return todo

    def _purge_cancelled(self, dq: "collections.deque[_Request]") -> int:
        """Drop requests whose ``Future.cancel()`` landed before the wave
        closed, so a cancelled request never occupies a wave row (caller
        holds ``_cv``).  Cancelled futures are already resolved —
        ``cancel()`` did that — so they only need forgetting here."""
        live = [r for r in dq if not r.future.cancelled()]
        gone = len(dq) - len(live)
        if gone:
            self._pending -= gone
            self._stats["cancelled"] += gone
            dq.clear()
            dq.extend(live)
        return gone

    def _expire(self, dq: "collections.deque[_Request]", now: float,
                out: List[_Request]) -> None:
        """Move dead requests out of a queue (caller holds ``_cv``)."""
        live = [r for r in dq
                if r.t_deadline is None or r.t_deadline > now]
        if len(live) != len(dq):
            out += [r for r in dq
                    if r.t_deadline is not None and r.t_deadline <= now]
            self._pending -= len(dq) - len(live)
            dq.clear()
            dq.extend(live)

    # -- dispatch (worker pool) ------------------------------------------

    def _dispatch(self, key: Optional[tuple],
                  wave: List[_Request]) -> None:
        try:
            live = [r for r in wave
                    if r.future.set_running_or_notify_cancel()]
            if not live:
                return
            if key is None:
                # non-coalescible requests: individual runs, one result
                # or exception each — a wave of width 1 apiece
                for r in live:
                    try:
                        r.future.set_result(
                            self.service.run(r.name, r.spec))
                        self._count(ok=1)
                    except Exception as e:
                        r.future.set_exception(e)
                        self._count(bad=1)
                    self._note_wave(1)
                return
            name, algo, pol = key
            pend = [_Pending(r.ticket, r.name, r.spec) for r in live]
            out = self.service._run_wave(name, algo, pol, pend)
            for r in live:
                res = out[r.ticket]
                if isinstance(res, Exception):
                    r.future.set_exception(res)
                    self._count(bad=1)
                else:
                    r.future.set_result(res)
                    self._count(ok=1)
            self._note_wave(len(live))
        finally:
            with self._cv:
                self._inflight -= 1
                self._cv.notify_all()

    def _count(self, ok: int = 0, bad: int = 0) -> None:
        with self._cv:
            self._stats["completed"] += ok
            self._stats["failed"] += bad

    def _note_wave(self, size: int) -> None:
        with self._cv:
            self._stats["waves"] += 1
            self._stats["wave_queries"] += size
            self._stats["coalesced_waves"] += 1 if size > 1 else 0
            self._stats["max_wave"] = max(self._stats["max_wave"], size)

    # -- introspection ---------------------------------------------------

    def stats(self) -> Dict[str, float]:
        with self._cv:
            s = dict(self._stats, pending=self._pending,
                     inflight=self._inflight)
        s["achieved_wave"] = (s["wave_queries"] / s["waves"]
                              if s["waves"] else 0.0)
        return s
