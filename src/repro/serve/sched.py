"""Wave scheduler — continuous batching for the graph front door.

The paper's asynchronous thesis applied to *serving*: a self-timed
element fires when its inputs are ready, not on a global clock.
``GraphService.gather`` is the bulk-synchronous version of batching —
only requests one caller queued before its barrier share a wave.
``WaveScheduler`` is the self-timed version: a background thread watches
the request stream from *all* clients, groups requests that resolve to
the same plan (``GraphService.wave_key``), and closes a wave the moment
it is worth dispatching — when a group reaches ``max_wave`` sources, or
when its oldest request has waited ``max_wait_s`` (the classic
continuous-batching policy of LLM serving engines; ``serve.engine.
ServeLoop`` plays the same game with decode slots).

Execution goes through ``GraphService._run_wave`` — the exact code path
``gather`` uses — so scheduled results are bit-identical to direct
``GraphService.run`` calls.  Requests carry an optional *deadline*; a
request that expires while queued resolves to ``DeadlineExceeded``
instead of occupying a row in a wave somebody else is waiting on.
``Future.cancel()`` before the wave closes is honored the same way: the
request is purged from its pending group at wave-close time and never
occupies a wave row (``stats()["cancelled"]`` counts them).

Failure handling (the self-healing half):

  * a wave that raises resolves ONLY that wave's futures — one bad
    request never takes down the scheduler loop or other waves;
  * *transient* failures (``repro.resilience.Transient`` — injected
    faults, wave watchdog timeouts) are retried: the request re-enters
    the queue after an exponential backoff with jitter, up to
    ``WavePolicy.max_retries`` attempts (``stats()["retries"]`` /
    ``["retry_exhausted"]``).  Deterministic errors (bad spec, plain
    ``RuntimeError``) are never retried — they would fail identically;
  * a *wave watchdog* (``WavePolicy.watchdog_s``) abandons dispatches
    that out-run a per-wave deadline scaled by the wave's plan cost
    (``GraphService.wave_cost``): the hung dispatch can no longer
    resolve futures, its worker slot is released so the scheduler keeps
    making progress, and its requests are retried or failed with a
    structured ``WaveTimeout`` (``stats()["watchdog_timeouts"]``);
  * ``stop(drain=False)`` resolves everything still pending with a
    structured ``ServerClosed`` (a ``Backpressure`` subclass) instead of
    leaving futures hanging forever.
"""

from __future__ import annotations

import collections
import dataclasses
import random
import threading
import time
from concurrent.futures import Future
from typing import Dict, List, Optional, Tuple

from .. import resilience
from ..core.api import QuerySpec
from .graph import GraphService, _Pending


class DeadlineExceeded(TimeoutError):
    """The request's deadline passed before a wave could serve it."""


class Backpressure(RuntimeError):
    """Admission control rejected a submit; ``stats`` says why (queue
    depth, plan-store thrash) so clients can back off intelligently."""

    def __init__(self, msg: str, stats: Optional[dict] = None):
        super().__init__(msg)
        self.stats = stats or {}


class ServerClosed(Backpressure):
    """The server/scheduler stopped before this request could run — the
    ultimate admission refusal.  Raised by ``GraphServer.submit`` on a
    closed server and set on every future ``stop(drain=False)``
    abandons, so no client ever blocks forever on a dead scheduler."""


class WaveTimeout(TimeoutError, resilience.Transient):
    """The wave watchdog abandoned a dispatch that out-ran its deadline.

    Transient by definition (a straggler shard, an injected hang) — the
    scheduler retries the wave's requests while budget remains."""


#: wave_cost units (plan tiles × sweeps × rows) that map to 1× the base
#: ``watchdog_s`` deadline; costlier waves get proportionally longer.
WATCHDOG_COST_REF = 1e8


@dataclasses.dataclass(frozen=True)
class WavePolicy:
    """Scheduler knobs (one frozen object, like ``ExecutionPolicy``).

    max_wave:    close a wave as soon as a plan-group holds this many
                 requests (rides on top of ``GraphService.max_wave``,
                 which re-chunks oversized groups defensively).
    max_wait_s:  close a wave when its oldest request has waited this
                 long, full or not — the latency half of the
                 continuous-batching trade.
    max_pending: admission control — submits beyond this many queued
                 requests are rejected with ``Backpressure``.
    workers:     dispatch slots.  1 (default) serializes waves (plan
                 builds never race); >1 lets waves for different plans
                 overlap.
    thrash_evictions / thrash_window_s:  reject submits while the shared
                 ``PlanStore`` evicted ≥ this many plans inside the
                 window — batching on top of a store that is re-building
                 plans per query only amplifies the thrash.
    max_retries: per-request retry budget for *transient* failures
                 (``resilience.is_transient``); 0 disables retries.
    backoff_base_s / backoff_cap_s / backoff_jitter:  retry n waits
                 ``min(cap, base·2ⁿ⁻¹)·(1 + jitter·U[0,1))`` before
                 re-entering the queue, so a flapping dependency is not
                 hammered in lockstep.
    watchdog_s:  per-wave deadline at ``WATCHDOG_COST_REF`` plan cost
                 (scaled up for costlier waves); ``None`` (default)
                 disables the watchdog.  An abandoned dispatch's thread
                 cannot be killed — its worker slot is released instead,
                 so true parallelism may briefly exceed ``workers``
                 while a hung wave winds down.
    """

    max_wave: int = 64
    max_wait_s: float = 0.005
    max_pending: int = 1024
    workers: int = 1
    thrash_evictions: int = 64
    thrash_window_s: float = 1.0
    max_retries: int = 2
    backoff_base_s: float = 0.05
    backoff_cap_s: float = 2.0
    backoff_jitter: float = 0.25
    watchdog_s: Optional[float] = None

    def __post_init__(self):
        if self.max_wave < 1:
            raise ValueError(f"max_wave must be >= 1: {self.max_wave!r}")
        if self.max_wait_s < 0:
            raise ValueError(
                f"max_wait_s must be >= 0: {self.max_wait_s!r}")
        if self.workers < 1:
            raise ValueError(f"workers must be >= 1: {self.workers!r}")
        if self.max_retries < 0:
            raise ValueError(
                f"max_retries must be >= 0: {self.max_retries!r}")
        if self.backoff_base_s < 0 or self.backoff_cap_s < 0 \
                or self.backoff_jitter < 0:
            raise ValueError(
                "backoff_base_s/backoff_cap_s/backoff_jitter must be "
                f">= 0: {self.backoff_base_s!r}/{self.backoff_cap_s!r}"
                f"/{self.backoff_jitter!r}")
        if self.watchdog_s is not None and self.watchdog_s <= 0:
            raise ValueError(
                f"watchdog_s must be > 0 or None: {self.watchdog_s!r}")

    def but(self, **kw) -> "WavePolicy":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass
class _Request:
    """One in-flight submit: a ``_Pending`` plus its future/deadline."""

    ticket: int
    name: str
    spec: QuerySpec
    key: Optional[tuple]            # GraphService.wave_key, None=solo
    future: Future
    t_submit: float                 # monotonic
    t_deadline: Optional[float]     # monotonic, None = no deadline
    attempt: int = 0                # retries consumed so far
    settled: bool = False           # resolution claimed (guarded by _cv)


@dataclasses.dataclass
class _Inflight:
    """One dispatched wave: the dispatcher thread races the watchdog
    for the right to resolve its requests (all flags under ``_cv``)."""

    key: Optional[tuple]
    wave: List[_Request]
    deadline: Optional[float]       # monotonic watchdog reap time
    wid: int = -1
    abandoned: bool = False         # watchdog gave up on the dispatcher
    slot_acquired: bool = False
    slot_released: bool = False
    thread: Optional[threading.Thread] = None


class WaveScheduler:
    """Background continuous-batching loop over a ``GraphService``.

    ``offer`` enqueues requests (thread-safe, any number of client
    threads); the scheduler thread closes waves per ``WavePolicy`` and
    dispatches each on its own worker thread (bounded by
    ``policy.workers`` slots), resolving each request's ``Future``.
    Not started until ``start()`` — a paused scheduler just accumulates
    requests, which is also what makes batching deterministic for tests
    and benchmarks.
    """

    def __init__(self, service: GraphService, policy: WavePolicy):
        self.service = service
        self.policy = policy
        self._cv = threading.Condition()
        self._groups: "collections.OrderedDict[tuple, " \
            "collections.deque[_Request]]" = collections.OrderedDict()
        self._singles: "collections.deque[_Request]" = collections.deque()
        self._pending = 0
        self._inflight = 0
        self._backoff = 0            # requests waiting out a retry delay
        self._running = False
        self._stopped = False
        self._thread: Optional[threading.Thread] = None
        self._entries: Dict[int, _Inflight] = {}
        self._next_wave_id = 0
        self._slots = threading.Semaphore(policy.workers)
        self._timers: Dict[int, Tuple[threading.Timer, _Request]] = {}
        self._rng = random.Random("repro-wave-backoff")
        self._stats = dict(waves=0, wave_queries=0, coalesced_waves=0,
                           max_wave=0, expired=0, cancelled=0,
                           completed=0, failed=0, retries=0,
                           retry_exhausted=0, watchdog_timeouts=0)

    # -- client side -----------------------------------------------------

    def offer(self, req: _Request) -> None:
        with self._cv:
            if not self._stopped:
                self._enqueue_locked(req)
                self._cv.notify_all()
                return
        # a stopped scheduler never leaves a future hanging
        if _claim(req.future):
            self._fail(req, ServerClosed("scheduler stopped",
                                         self.stats()))

    def _enqueue_locked(self, req: _Request) -> None:
        req.settled = False
        if req.key is not None:
            self._groups.setdefault(
                req.key, collections.deque()).append(req)
        else:
            self._singles.append(req)
        self._pending += 1

    def pending(self) -> int:
        with self._cv:
            return self._pending

    def evict(self, name: str) -> int:
        """Resolve every queued request for ``name`` with ``KeyError``
        (mirrors ``GraphService.evict``'s promise that pending tickets
        are never silently dropped).  Returns how many were resolved."""
        err = KeyError(f"graph {name!r} was evicted before the query "
                       "ran")
        with self._cv:
            victims: List[_Request] = []
            for key in list(self._groups):
                dq = self._groups[key]
                keep = collections.deque(
                    r for r in dq if r.name != name)
                victims += [r for r in dq if r.name == name]
                if keep:
                    self._groups[key] = keep
                else:
                    del self._groups[key]
            keep = collections.deque(
                r for r in self._singles if r.name != name)
            victims += [r for r in self._singles if r.name == name]
            self._singles = keep
            self._pending -= len(victims)
            for r in victims:
                r.settled = True
            self._cv.notify_all()
        for r in victims:
            if _claim(r.future):
                r.future.set_exception(err)
        return len(victims)

    # -- lifecycle -------------------------------------------------------

    def start(self) -> None:
        with self._cv:
            if self._running:
                return
            self._running = True
            self._thread = threading.Thread(target=self._loop,
                                            name="repro-wave-sched",
                                            daemon=True)
            self._thread.start()

    def stop(self, drain: bool = True, timeout: Optional[float] = None
             ) -> None:
        """Stop the loop.  ``drain=True`` (default) dispatches every
        queued request first — full wave or not; ``drain=False`` fails
        the queue (and anything parked in retry backoff or stuck
        in-flight) with a structured ``ServerClosed``, so every
        outstanding future resolves."""
        with self._cv:
            self._stopped = True
            self._running = False
            self._cv.notify_all()
            thread, self._thread = self._thread, None
            # claim every parked retry: popping the timer token is the
            # ownership handoff (a timer that already fired owns itself)
            parked: List[_Request] = []
            for k in list(self._timers):
                t, req = self._timers.pop(k)
                t.cancel()
                self._backoff -= 1
                parked.append(req)
        if thread is not None:
            thread.join(timeout)
        if drain:
            with self._cv:
                for req in parked:
                    self._enqueue_locked(req)
            for key, wave in self._close_waves(force=True):
                ent = self._register_wave(key, wave)
                self._dispatch(ent)       # synchronous final flush
            self._join_inflight(timeout=None)
        else:
            err = ServerClosed("scheduler stopped", self.stats())
            for req in parked:
                if _claim(req.future):
                    self._fail(req, err)
            for _, wave in self._close_waves(force=True):
                for r in wave:
                    if _claim(r.future):
                        self._fail(r, err)
                with self._cv:
                    self._inflight -= 1
                    self._cv.notify_all()
            self._join_inflight(timeout=timeout if timeout is not None
                                else 5.0)
            self._reap_all(err)

    def _join_inflight(self, timeout: Optional[float]) -> None:
        with self._cv:
            threads = [e.thread for e in self._entries.values()
                       if e.thread is not None]
        end = None if timeout is None else time.monotonic() + timeout
        for t in threads:
            left = None if end is None else max(end - time.monotonic(),
                                                0.0)
            t.join(left)

    def _reap_all(self, err: Exception) -> None:
        """Abandon every still-inflight wave (dispatcher threads that
        out-lived the stop timeout) and resolve their requests."""
        doomed: List[Tuple[_Inflight, List[_Request]]] = []
        with self._cv:
            for wid in list(self._entries):
                ent = self._entries.pop(wid)
                ent.abandoned = True
                victims = [r for r in ent.wave if not r.settled]
                for r in victims:
                    r.settled = True
                self._inflight -= 1
                doomed.append((ent, victims))
            if doomed:
                self._cv.notify_all()
        for ent, victims in doomed:
            self._release_slot(ent)
            for r in victims:
                if _claim(r.future):
                    self._fail(r, err)

    def drain(self, timeout: Optional[float] = None) -> bool:
        """Block until the queue, in-flight waves AND retry backoffs are
        empty (or ``timeout``); True if fully drained."""
        end = None if timeout is None else time.monotonic() + timeout
        with self._cv:
            while self._pending or self._inflight or self._backoff:
                left = None if end is None else end - time.monotonic()
                if left is not None and left <= 0:
                    return False
                self._cv.wait(timeout=left)
        return True

    # -- the scheduling loop ---------------------------------------------

    def _loop(self) -> None:
        while True:
            with self._cv:
                if not self._running:
                    return   # stop() owns the final flush
                now = time.monotonic()
                due = self._next_event()
                if due is None or due > now:
                    wait = None if due is None else max(due - now, 1e-4)
                    self._cv.wait(timeout=wait)
                    if not self._running:
                        return
            for key, wave in self._close_waves(force=False):
                ent = self._register_wave(key, wave)
                t = threading.Thread(
                    target=self._dispatch, args=(ent,),
                    name="repro-wave-dispatch", daemon=True)
                ent.thread = t
                t.start()
            self._reap_overdue()

    def _next_event(self) -> Optional[float]:
        """Earliest moment anything becomes actionable (caller holds
        ``_cv``): a single to run, a group's max-wait expiry, a full
        group (already due), a request deadline, or a watchdog reap."""
        now = time.monotonic()
        due: Optional[float] = None

        def upd(t: float):
            nonlocal due
            due = t if due is None else min(due, t)

        if self._singles:
            upd(now)
        for dq in self._groups.values():
            if len(dq) >= self.policy.max_wave:
                upd(now)
            elif dq:
                upd(dq[0].t_submit + self.policy.max_wait_s)
        for dq in list(self._groups.values()) + [self._singles]:
            for r in dq:
                if r.t_deadline is not None:
                    upd(r.t_deadline)
        for ent in self._entries.values():
            if ent.deadline is not None and not ent.abandoned:
                upd(ent.deadline)
        return due

    def _close_waves(self, force: bool
                     ) -> List[Tuple[Optional[tuple], List[_Request]]]:
        """Pop every wave that is ready (full / waited out / forced),
        expiring dead-on-arrival requests first so they never occupy a
        row.  Returns [(wave_key or None, requests)]."""
        expired: List[_Request] = []
        todo: List[Tuple[Optional[tuple], List[_Request]]] = []
        now = time.monotonic()
        with self._cv:
            ncancel = self._purge_cancelled(self._singles)
            for dq in self._groups.values():
                ncancel += self._purge_cancelled(dq)
            self._expire(self._singles, now, expired)
            if self._singles:
                wave = list(self._singles)
                self._singles.clear()
                self._pending -= len(wave)
                self._inflight += 1
                todo.append((None, wave))
            for key in list(self._groups):
                dq = self._groups[key]
                self._expire(dq, now, expired)
                while dq and (force or len(dq) >= self.policy.max_wave
                              or now - dq[0].t_submit
                              >= self.policy.max_wait_s):
                    wave = [dq.popleft() for _ in
                            range(min(len(dq), self.policy.max_wave))]
                    self._pending -= len(wave)
                    self._inflight += 1
                    todo.append((key, wave))
                if not dq:
                    del self._groups[key]
            self._stats["expired"] += len(expired)
            for r in expired:
                r.settled = True
            if expired or ncancel:
                self._cv.notify_all()
        for r in expired:
            if _claim(r.future):
                r.future.set_exception(DeadlineExceeded(
                    f"deadline exceeded after "
                    f"{now - r.t_submit:.3f}s in queue "
                    f"({r.spec.algo} on {r.name!r})"))
        return todo

    def _purge_cancelled(self, dq: "collections.deque[_Request]") -> int:
        """Drop requests whose ``Future.cancel()`` landed before the wave
        closed, so a cancelled request never occupies a wave row (caller
        holds ``_cv``).  Cancelled futures are already resolved —
        ``cancel()`` did that — so they only need forgetting here."""
        live = [r for r in dq if not r.future.cancelled()]
        gone = len(dq) - len(live)
        if gone:
            self._pending -= gone
            self._stats["cancelled"] += gone
            dq.clear()
            dq.extend(live)
        return gone

    def _expire(self, dq: "collections.deque[_Request]", now: float,
                out: List[_Request]) -> None:
        """Move dead requests out of a queue (caller holds ``_cv``)."""
        live = [r for r in dq
                if r.t_deadline is None or r.t_deadline > now]
        if len(live) != len(dq):
            out += [r for r in dq
                    if r.t_deadline is not None and r.t_deadline <= now]
            self._pending -= len(dq) - len(live)
            dq.clear()
            dq.extend(live)

    # -- dispatch (per-wave worker threads) ------------------------------

    def _register_wave(self, key: Optional[tuple],
                       wave: List[_Request]) -> _Inflight:
        """Record one closed wave as in-flight (``_close_waves`` already
        counted it) so the watchdog can see it."""
        ent = _Inflight(key, wave, self._wave_deadline(key, wave))
        with self._cv:
            wid = self._next_wave_id
            self._next_wave_id += 1
            self._entries[wid] = ent
            ent.wid = wid
        return ent

    def _wave_deadline(self, key: Optional[tuple],
                       wave: List[_Request]) -> Optional[float]:
        ws = self.policy.watchdog_s
        if ws is None:
            return None
        if key is not None:
            name, algo, pol = key
            try:
                cost = self.service.wave_cost(name, algo, pol,
                                              rows=len(wave))
            except Exception:   # evicted graph etc. — use the base
                cost = WATCHDOG_COST_REF
            scale = max(1.0, cost / WATCHDOG_COST_REF)
        else:
            scale = max(1.0, float(len(wave)))
        return time.monotonic() + ws * scale

    def _dispatch(self, ent: _Inflight) -> None:
        try:
            self._slots.acquire()
            with self._cv:
                ent.slot_acquired = True
                reaped = ent.abandoned
            if not reaped:
                self._execute_wave(ent)
        finally:
            self._release_slot(ent)
            with self._cv:
                self._entries.pop(ent.wid, None)
                if not ent.abandoned:
                    # a reaped wave was already discounted by its reaper
                    self._inflight -= 1
                self._cv.notify_all()

    def _execute_wave(self, ent: _Inflight) -> None:
        key, wave = ent.key, ent.wave
        live = [r for r in wave if _claim(r.future)]
        if not live:
            return
        try:
            resilience.fire("sched.dispatch",
                            name=key[0] if key else None,
                            algo=key[1] if key else None,
                            size=len(live))
        except Exception as e:
            for r in live:
                if self._take(ent, r):
                    self._resolve_failure(r, e)
            self._note_wave(len(live))
            return
        if key is None:
            # non-coalescible requests: individual runs, one result
            # or exception each — a wave of width 1 apiece
            for r in live:
                try:
                    res = self.service.run(r.name, r.spec)
                except Exception as e:
                    if self._take(ent, r):
                        self._resolve_failure(r, e)
                else:
                    if self._take(ent, r):
                        self._ok(r, res)
                self._note_wave(1)
            return
        name, algo, pol = key
        pend = [_Pending(r.ticket, r.name, r.spec) for r in live]
        try:
            out = self.service._run_wave(name, algo, pol, pend)
        except Exception as e:   # defensive: _run_wave maps per-ticket
            out = {r.ticket: e for r in live}
        for r in live:
            res = out[r.ticket]
            if not self._take(ent, r):
                continue
            if isinstance(res, Exception):
                self._resolve_failure(r, res)
            else:
                self._ok(r, res)
        self._note_wave(len(live))

    def _take(self, ent: _Inflight, req: _Request) -> bool:
        """Dispatcher-side claim of one request's resolution; loses to
        a watchdog that already reaped the wave."""
        with self._cv:
            if ent.abandoned or req.settled:
                return False
            req.settled = True
            return True

    def _release_slot(self, ent: _Inflight) -> None:
        with self._cv:
            if not ent.slot_acquired or ent.slot_released:
                return
            ent.slot_released = True
        self._slots.release()

    # -- watchdog --------------------------------------------------------

    def _reap_overdue(self) -> None:
        """Abandon in-flight waves past their deadline: the dispatcher
        loses resolution rights, its slot is freed, and each request is
        retried (``WaveTimeout`` is transient) or failed."""
        now = time.monotonic()
        doomed: List[Tuple[_Inflight, List[_Request], float]] = []
        with self._cv:
            for wid in list(self._entries):
                ent = self._entries[wid]
                if ent.deadline is None or ent.abandoned \
                        or now < ent.deadline:
                    continue
                ent.abandoned = True
                victims = [r for r in ent.wave if not r.settled]
                for r in victims:
                    r.settled = True
                del self._entries[wid]
                self._inflight -= 1
                self._stats["watchdog_timeouts"] += 1
                doomed.append((ent, victims, now))
            if doomed:
                self._cv.notify_all()
        for ent, victims, t in doomed:
            self._release_slot(ent)
            for r in victims:
                self._resolve_failure(r, WaveTimeout(
                    f"wave watchdog reaped dispatch after "
                    f"{t - r.t_submit:.3f}s "
                    f"({r.spec.algo} on {r.name!r}, "
                    f"attempt {r.attempt + 1})"))

    # -- retry / failure resolution --------------------------------------

    def _resolve_failure(self, req: _Request, exc: Exception) -> None:
        """Settle one failed request: schedule a backoff retry when the
        error is transient and budget remains, else fail the future."""
        transient = resilience.is_transient(exc)
        with self._cv:
            stopped = self._stopped
        if transient and req.attempt < self.policy.max_retries \
                and not stopped:
            req.attempt += 1
            p = self.policy
            delay = min(p.backoff_cap_s,
                        p.backoff_base_s * (2 ** (req.attempt - 1)))
            with self._cv:
                delay *= 1.0 + p.backoff_jitter * self._rng.random()
                timer = threading.Timer(delay, self._requeue,
                                        args=(req,))
                timer.daemon = True
                self._stats["retries"] += 1
                self._backoff += 1
                self._timers[id(req)] = (timer, req)
            timer.start()
            return
        if transient and req.attempt >= self.policy.max_retries:
            with self._cv:
                self._stats["retry_exhausted"] += 1
        elif transient and stopped:
            closed = ServerClosed(
                f"scheduler stopped before retrying "
                f"{type(exc).__name__}: {exc}", self.stats())
            closed.__cause__ = exc
            exc = closed
        self._fail(req, exc)

    def _requeue(self, req: _Request) -> None:
        """Timer callback: put a backed-off request back in the queue
        (or fail it if the scheduler stopped while it was parked)."""
        with self._cv:
            if self._timers.pop(id(req), None) is None:
                return   # stop() claimed this retry
            self._backoff -= 1
            stopped = self._stopped
            if not stopped:
                self._enqueue_locked(req)
            self._cv.notify_all()
        if stopped:
            self._fail(req, ServerClosed(
                "scheduler stopped during retry backoff", self.stats()))

    def _ok(self, req: _Request, res) -> None:
        try:
            req.future.set_result(res)
        except Exception:    # lost a cancel race; nothing to report
            return
        self._count(ok=1)

    def _fail(self, req: _Request, exc: Exception) -> None:
        try:
            req.future.set_exception(exc)
        except Exception:    # lost a cancel race; nothing to report
            return
        self._count(bad=1)

    def _count(self, ok: int = 0, bad: int = 0) -> None:
        with self._cv:
            self._stats["completed"] += ok
            self._stats["failed"] += bad

    def _note_wave(self, size: int) -> None:
        with self._cv:
            self._stats["waves"] += 1
            self._stats["wave_queries"] += size
            self._stats["coalesced_waves"] += 1 if size > 1 else 0
            self._stats["max_wave"] = max(self._stats["max_wave"], size)

    # -- introspection ---------------------------------------------------

    def stats(self) -> Dict[str, float]:
        with self._cv:
            s = dict(self._stats, pending=self._pending,
                     inflight=self._inflight,
                     retry_backlog=self._backoff)
        s["achieved_wave"] = (s["wave_queries"] / s["waves"]
                              if s["waves"] else 0.0)
        return s


def _claim(fut: Future) -> bool:
    """Move a future to RUNNING if possible.  A retried request's
    future is already RUNNING from its first dispatch — still ours to
    resolve (RUNNING futures can't be cancelled, and only the scheduler
    finishes them), without tripping the stdlib's unexpected-state
    alarm in ``set_running_or_notify_cancel``."""
    if fut.running():
        return True
    try:
        return fut.set_running_or_notify_cancel()
    except RuntimeError:    # lost a state race anyway
        return not fut.done()
