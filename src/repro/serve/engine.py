"""Batched serving: prefill + decode loop, greedy/temperature sampling,
and a slot-based continuous-batching scheduler.

``generate`` is the static-batch path (one wave of prompts decoded
together).  ``ServeLoop`` keeps a fixed pool of B slots with a shared
batched KV cache; finished slots are refilled from the queue in *waves*
(batch prefill), and the per-leaf "batch" position comes from the cache's
logical axes so slot surgery works for every cache family (KV / latent /
ring / recurrent state)."""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models import lm
from ..sharding.rules import parse_axes


def _sample(logits, key, temperature: float):
    if temperature == 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature).astype(
        jnp.int32)


def generate(cfg: ModelConfig, params, prompts: jnp.ndarray,
             max_new_tokens: int, temperature: float = 0.0,
             key: Optional[jax.Array] = None,
             extras: Optional[Dict] = None,
             eos: Optional[int] = None) -> np.ndarray:
    """prompts: (B, S) int32.  Returns (B, S + max_new) tokens."""
    b, s = prompts.shape
    key = key if key is not None else jax.random.PRNGKey(0)
    cache_len = s + max_new_tokens
    batch = {"tokens": prompts, **(extras or {})}
    logits, cache = jax.jit(
        lambda p, bt: lm.prefill(cfg, p, bt, cache_len=cache_len)
    )(params, batch)

    step_fn = jax.jit(
        lambda p, c, t, pos: lm.decode_step(cfg, p, c, t, pos))
    out = [np.asarray(prompts)]
    tok = _sample(logits, key, temperature)
    done = np.zeros(b, dtype=bool)
    for i in range(max_new_tokens):
        out.append(np.asarray(tok)[:, None])
        if eos is not None:
            done |= np.asarray(tok) == eos
            if done.all():
                pad = np.full((b, max_new_tokens - i - 1), eos, np.int32)
                if pad.shape[1]:
                    out.append(pad)
                break
        if i == max_new_tokens - 1:
            break
        key, sk = jax.random.split(key)
        logits, cache = step_fn(params, cache, tok, jnp.int32(s + i))
        tok = _sample(logits, sk, temperature)
    return np.concatenate(out, axis=1)


# ---------------------------------------------------------------------------
# continuous batching (slot pool)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeLoop:
    """Fixed B-slot decode pool with wave prefill."""

    def __init__(self, cfg: ModelConfig, params, num_slots: int,
                 cache_len: int, extras_fn=None):
        self.cfg, self.params = cfg, params
        self.b, self.cache_len = num_slots, cache_len
        self.extras_fn = extras_fn or (lambda n: {})
        self.cache = lm.init_cache(cfg, num_slots, cache_len)
        self.cache_batch_dim = jax.tree.map(
            lambda ax: parse_axes(ax).index("batch"), lm.cache_axes(cfg))
        self.slot_req: List[Optional[Request]] = [None] * num_slots
        self.slot_pos = np.zeros(num_slots, dtype=np.int64)
        self.last_tok = np.zeros(num_slots, dtype=np.int32)
        self.queue: List[Request] = []
        self._step = jax.jit(
            lambda p, c, t, pos: lm.decode_step(cfg, p, c, t, pos))
        self._prefill = jax.jit(
            lambda p, bt: lm.prefill(cfg, p, bt,
                                     cache_len=self.cache_len))

    def submit(self, req: Request):
        self.queue.append(req)

    def _free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def _admit_wave(self):
        free = self._free_slots()
        wave = []
        while free and self.queue:
            wave.append((free.pop(0), self.queue.pop(0)))
        if not wave:
            return
        maxlen = max(len(r.prompt) for _, r in wave)
        toks = np.zeros((len(wave), maxlen), np.int32)
        for i, (_, r) in enumerate(wave):
            toks[i, maxlen - len(r.prompt):] = r.prompt  # left-pad
        batch = {"tokens": jnp.asarray(toks),
                 **self.extras_fn(len(wave))}
        logits, wave_cache = self._prefill(self.params, batch)
        tok = np.asarray(jnp.argmax(logits, -1), np.int32)
        slots = [s for s, _ in wave]
        self.cache = jax.tree.map(
            lambda c, w, d: c.at[(slice(None),) * d +
                                 (np.asarray(slots),)].set(
                w.astype(c.dtype)),
            self.cache, wave_cache, self.cache_batch_dim)
        for i, (s, r) in enumerate(wave):
            self.slot_req[s] = r
            self.slot_pos[s] = maxlen
            self.last_tok[s] = tok[i]
            r.generated.append(int(tok[i]))

    def step(self):
        """One decode step for all active slots (+ admit new work)."""
        self._admit_wave()
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return False
        pos = jnp.asarray(self.slot_pos, jnp.int32)
        logits, self.cache = self._step(self.params, self.cache,
                                        jnp.asarray(self.last_tok), pos)
        tok = np.asarray(jnp.argmax(logits, -1), np.int32)
        for s in active:
            r = self.slot_req[s]
            r.generated.append(int(tok[s]))
            self.slot_pos[s] += 1
            self.last_tok[s] = tok[s]
            if len(r.generated) >= r.max_new or \
                    self.slot_pos[s] >= self.cache_len - 1:
                r.done = True
                self.slot_req[s] = None
        return True

    def run(self, max_steps: int = 10_000):
        steps = 0
        while (self.queue or any(self.slot_req)) and steps < max_steps:
            self.step()
            steps += 1
        return steps
