from .engine import ServeLoop, generate  # noqa: F401
