from .graph import GraphService, PlanStore  # noqa: F401
from .sched import (Backpressure, DeadlineExceeded,  # noqa: F401
                    ServerClosed, WavePolicy, WaveScheduler,
                    WaveTimeout)
from .server import GraphServer  # noqa: F401

__all__ = ["ServeLoop", "generate", "GraphService", "PlanStore",
           "GraphServer", "WaveScheduler", "WavePolicy",
           "DeadlineExceeded", "Backpressure", "ServerClosed",
           "WaveTimeout"]


def __getattr__(name):
    # the LM serving loop pulls in the whole model/config stack; load it
    # lazily so graph-only users of repro.api don't pay for it
    if name in ("ServeLoop", "generate"):
        from . import engine
        return getattr(engine, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
