"""GraphServer — the network front door over ``GraphService``.

``GraphService.submit/gather`` batches only what one caller queued
before its own barrier; ``GraphServer`` makes batching happen *across*
concurrent clients, which is what "millions of users" actually send:

  * ``submit(name, spec, deadline=None) → Future`` from any number of
    threads; a background ``WaveScheduler`` closes batched waves on a
    max-wait / max-batch policy (continuous batching) and dispatches
    them through the existing batched vmap / 2-D mesh engines — off the
    caller's thread, results bit-identical to direct
    ``GraphService.run``.
  * request deadlines — an expired request resolves to
    ``DeadlineExceeded`` instead of occupying a wave row;
  * admission control — submits are refused with ``Backpressure`` (and
    a stats payload) while the queue is over ``max_pending`` or the
    shared ``PlanStore`` is thrashing;
  * plan warming — ``register()`` consults the access log the store
    persists beside its on-disk plan tier and speculatively prepares
    the graph's hot plans in the background, so a restarted server is
    warm before its first request;
  * self-healing — transient wave failures retry with exponential
    backoff, a watchdog reaps hung dispatches, and ``close`` /
    ``submit`` on a closed server resolve with a structured
    ``ServerClosed`` (see ``serve.sched`` and ``repro.resilience``).

    server = GraphServer(cache_dir="~/.cache/repro-plans")
    server.register("roads", g, b=16, num_clusters=64)
    fut = server.submit("roads", QuerySpec(algo="sssp", sources=(0,)),
                        deadline=0.5)
    dist = fut.result().values           # waves close in the background
    server.close()
"""

from __future__ import annotations

import collections
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from concurrent.futures import TimeoutError as _FutureTimeout
from typing import List, Optional

from ..core.api import QuerySpec, Result
from ..core.graph import Graph
from .graph import GraphService
from .sched import (Backpressure, ServerClosed, WavePolicy,
                    WaveScheduler, _Request)


class GraphServer:
    """Concurrent-client front end: futures in, batched waves out.

    Wraps an existing ``GraphService`` (pass ``service=``) or builds its
    own (remaining keyword arguments go to ``GraphService``).  The wave
    scheduler's knobs live in one ``WavePolicy``; ``autostart=False``
    leaves the scheduler paused — submits then just accumulate until
    ``start()``, which is also how tests and benchmarks get
    deterministic wave shapes.
    """

    def __init__(self, service: Optional[GraphService] = None, *,
                 wave: Optional[WavePolicy] = None,
                 warm_limit: int = 4, autostart: bool = True,
                 **service_kw):
        if service is not None and service_kw:
            raise ValueError(
                "pass either a service= or GraphService kwargs "
                f"({sorted(service_kw)}), not both")
        self.service = service or GraphService(**service_kw)
        self.wave = wave or WavePolicy(max_wave=self.service.max_wave)
        self.warm_limit = int(warm_limit)
        self.sched = WaveScheduler(self.service, self.wave)
        self._lock = threading.Lock()
        self._next_ticket = 0
        self._closed = False
        self._rejected_pending = 0
        self._rejected_thrash = 0
        self._plans_warmed = 0
        self._warm_failed = 0
        self._warm_futures: List[Future] = []
        self._warm_pool = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="repro-warm")
        # (monotonic, evictions) samples for the thrash detector
        self._evict_samples: "collections.deque[tuple]" = \
            collections.deque()
        if autostart:
            self.start()

    # -- lifecycle -------------------------------------------------------

    def start(self) -> None:
        self.sched.start()

    def close(self, drain: bool = True) -> None:
        """Stop serving.  ``drain=True`` completes every queued request
        first; the plan access log is flushed so the next process can
        warm what this one found hot."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
        self.sched.stop(drain=drain)
        self._warm_pool.shutdown(wait=True)
        self.service.store.flush_access_log()

    def __enter__(self) -> "GraphServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- registry (delegates + plan warming) -----------------------------

    def register(self, name: str, g: Graph, warm: Optional[bool] = None,
                 **kw):
        """``GraphService.register`` plus background plan warming: the
        store's persisted access log names this graph's hot plans; each
        (up to ``warm_limit``, hottest first) is prepared off-thread —
        from the disk tier when present, rebuilt when not — so the
        first real request finds its plan resident.  ``warm=False``
        opts a registration out; ``wait_warm()`` joins the work."""
        proc = self.service.register(name, g, **kw)
        if warm is None:
            warm = self.warm_limit > 0
        if not warm:
            return proc
        # only keys this registration's session parameters can rebuild
        hot = [k for k in
               self.service.store.hot_keys(g.fingerprint())
               if (k.b, k.num_clusters, k.clustered, k.seed)
               == (proc.b, proc.num_clusters, proc.clustered,
                   proc.seed)]
        for key in hot[:self.warm_limit]:
            self._warm_futures.append(self._warm_pool.submit(
                self._warm_one, proc, key))
        return proc

    def _warm_one(self, proc, key) -> None:
        try:
            proc.prepare(key.semiring, variant=key.variant,
                         pull=key.pull, normalize=key.normalize)
            with self._lock:
                self._plans_warmed += 1
        except Exception:
            # warming is speculative: a failure costs nothing but the
            # head start (the plan will build on first demand instead)
            with self._lock:
                self._warm_failed += 1

    def wait_warm(self, timeout: Optional[float] = None) -> bool:
        """Block until background warming settles; True if it all did."""
        end = None if timeout is None else time.monotonic() + timeout
        for f in list(self._warm_futures):
            left = None if end is None else max(end - time.monotonic(),
                                                0.0)
            try:
                # on py3.10 futures raise their own TimeoutError class
                f.exception(timeout=left)
            except (TimeoutError, _FutureTimeout):
                return False
        return True

    def evict(self, name: str) -> None:
        """Drop a graph AND resolve its queued requests to KeyError."""
        self.service.evict(name)
        self.sched.evict(name)

    # -- admission + submit ----------------------------------------------

    def _thrashing(self) -> bool:
        """True while the shared PlanStore evicted ≥ ``thrash_evictions``
        plans inside the trailing ``thrash_window_s``: the working set
        no longer fits, so admitting more load just converts every
        query into a compile-pipeline run."""
        pol = self.wave
        if pol.thrash_evictions <= 0:
            return False
        now = time.monotonic()
        ev = self.service.store.stats()["evictions"]
        with self._lock:
            self._evict_samples.append((now, ev))
            horizon = now - pol.thrash_window_s
            while (len(self._evict_samples) > 1
                   and self._evict_samples[0][0] < horizon):
                self._evict_samples.popleft()
            delta = ev - self._evict_samples[0][1]
        return delta >= pol.thrash_evictions

    def submit(self, name: str, spec: QuerySpec,
               deadline: Optional[float] = None) -> Future:
        """Enqueue one query; returns a ``concurrent.futures.Future``.

        ``deadline`` is a per-request latency budget in seconds: if no
        wave has served the request by then it resolves to
        ``DeadlineExceeded`` (never occupying a wave row past its use).
        Raises ``KeyError``/``ValueError`` for bad requests and
        ``Backpressure`` when admission control refuses new load.
        """
        if self._closed:
            raise ServerClosed("GraphServer is closed")
        queued = self.sched.pending()
        if queued >= self.wave.max_pending:
            with self._lock:
                self._rejected_pending += 1
            raise Backpressure(
                f"pending queue is full ({queued} >= "
                f"{self.wave.max_pending})", self.stats())
        if self._thrashing():
            with self._lock:
                self._rejected_thrash += 1
            raise Backpressure(
                "plan store is thrashing "
                f"(>= {self.wave.thrash_evictions} evictions in "
                f"{self.wave.thrash_window_s}s)", self.stats())
        key = self.service.wave_key(name, spec)  # validates, fail-fast
        now = time.monotonic()
        fut: Future = Future()
        with self._lock:
            ticket = self._next_ticket
            self._next_ticket += 1
        self.sched.offer(_Request(
            ticket=ticket, name=name, spec=spec, key=key, future=fut,
            t_submit=now,
            t_deadline=None if deadline is None else now + deadline))
        return fut

    def run(self, name: str, spec: QuerySpec,
            deadline: Optional[float] = None) -> Result:
        """Blocking convenience: ``submit`` + ``result()``."""
        return self.submit(name, spec, deadline=deadline).result()

    def submit_async(self, name: str, spec: QuerySpec,
                     deadline: Optional[float] = None):
        """Asyncio adapter: returns an awaitable for the same request
        (``await server.submit_async(...)`` from a coroutine).  The
        wave scheduler stays thread-based; only the completion hop is
        bridged onto the running event loop."""
        import asyncio
        return asyncio.wrap_future(
            self.submit(name, spec, deadline=deadline))

    # -- introspection ---------------------------------------------------

    def stats(self) -> dict:
        with self._lock:
            s = dict(rejected_pending=self._rejected_pending,
                     rejected_thrash=self._rejected_thrash,
                     plans_warmed=self._plans_warmed,
                     warm_failed=self._warm_failed)
        return {"server": s, "scheduler": self.sched.stats(),
                "service": self.service.stats()}
