"""GraphService — the multi-graph serving gateway.

The paper's amortization argument, taken to system scale: compile-time
work (profile → cluster → place → BSR build, Fig. 4) is done once and
*kept*, so the run-time engines serve queries at run-time speed.  PR 1's
``GraphProcessor`` holds that split per process; this module holds it per
*fleet*:

  * ``PlanStore`` — a bounded LRU of ``Prepared`` plan images keyed by
    ``(graph_fingerprint, PlanKey)`` with byte-size accounting, shared by
    every graph registered in a service, and backed by a persistent
    on-disk cache so a restarted process warm-loads plans instead of
    re-running the compile pipeline (PIUMA / GraphScale's load-once /
    query-many shape surviving the process boundary).

  * ``GraphService`` — the front door: a named graph registry
    (``register / get / evict``), direct ``run``, and a ``submit(...) →
    ticket`` / ``gather()`` queue that coalesces same-plan single-source
    requests of coalescible algorithms (``AlgorithmSpec.coalescible``:
    SSSP/BFS out of the box) into one batched vmap run (the slot/wave pattern of
    ``serve.engine.ServeLoop``, with the query axis playing the slot
    axis).

    svc = GraphService(cache_dir="~/.cache/repro-plans",
                       max_plan_bytes=256 << 20)
    svc.register("roads", g, b=16, num_clusters=64)
    t0 = svc.submit("roads", QuerySpec(algo="sssp", sources=(0,)))
    t1 = svc.submit("roads", QuerySpec(algo="sssp", sources=(9,)))
    out = svc.gather()        # one batched run served both tickets
"""

from __future__ import annotations

import collections
import dataclasses
import hashlib
import json
import os
import threading
import time
import warnings
import zipfile
from typing import Dict, List, Optional, Tuple, Union

import numpy as np

from .. import resilience
from ..core import engine as eng
from ..core.algorithms import get_algorithm, registered_algorithms
from ..core.api import (ExecutionPolicy, GraphProcessor, PlanKey, QuerySpec,
                        Result, validate_spec)
from ..core.engine import Prepared
from ..core.graph import Graph
from ..kernels.spec import KernelSpec


def _coalescible() -> Tuple[str, ...]:
    """Algorithms whose single-source requests can share one batched
    run — declared per-algorithm on the ``AlgorithmSpec`` registry, so
    user-registered algorithms opt in without touching the serving
    layer."""
    return tuple(n for n in registered_algorithms()
                 if get_algorithm(n).coalescible)


# back-compat alias (snapshotted at import; wave_key consults the
# registry live)
COALESCIBLE = _coalescible()


def _plan_filename(fingerprint: str, key: PlanKey) -> str:
    kd = hashlib.blake2b(repr(key).encode(), digest_size=12).hexdigest()
    return f"{fingerprint}-{kd}.plan.npz"


# the plan access log lives beside the serialized plans; it is what lets
# a restarted server *warm* a graph's hot plans at register() time
# instead of on the first unlucky request (serve.server.GraphServer)
ACCESS_LOG = "plan_access.json"
# measured kernel tunings (kernels/autotune.py records) keyed like plans:
# (fingerprint, PlanKey-with-kernel) — the persistent tier is what makes
# a warm restart reuse tunings instead of re-measuring
TUNINGS_LOG = "plan_tunings.json"
_ACCESS_FLUSH_S = 1.0   # throttle: at most one log write per second
# corrupt cache files are MOVED here (not deleted): evidence survives
# for postmortems while the live path starts fresh
QUARANTINE_DIR = "quarantine"


def _json_checksum(obj) -> str:
    """Content digest for the JSON sidecar logs (tunings / access):
    computed over the canonical serialization of the payload half, so a
    truncated or hand-mangled file fails loudly at load instead of
    feeding half a log back into the warm path."""
    blob = json.dumps(obj, sort_keys=True).encode()
    return hashlib.blake2b(blob, digest_size=16).hexdigest()


def _key_to_json(key: PlanKey) -> dict:
    return dataclasses.asdict(key)  # nested KernelSpec → nested dict


def _key_from_json(d: dict) -> PlanKey:
    kd = d.get("kernel")
    if kd is not None and not isinstance(kd, KernelSpec):
        d = dict(d, kernel=KernelSpec(**kd))
    return PlanKey(**d)


class PlanStore:
    """Bounded LRU of ``Prepared`` images with a persistent disk tier.

    Memory tier: an ordered map ``(fingerprint, PlanKey) → Prepared``
    with byte-size accounting (``Prepared.nbytes``); inserting past
    ``max_bytes`` evicts least-recently-used plans.  Disk tier (optional
    ``cache_dir``): every built plan is serialized on ``put``; a memory
    miss falls through to disk before reporting a miss, so evicted and
    cross-process plans reload without re-running the compile pipeline.
    """

    def __init__(self, max_bytes: int = 256 << 20,
                 cache_dir: Optional[str] = None):
        self.max_bytes = int(max_bytes)
        self.cache_dir = os.path.expanduser(cache_dir) if cache_dir \
            else None
        if self.cache_dir:
            os.makedirs(self.cache_dir, exist_ok=True)
        self._mem: "collections.OrderedDict[Tuple[str, PlanKey], " \
            "Tuple[Prepared, int]]" = collections.OrderedDict()
        self._bytes = 0
        self._lock = threading.RLock()
        self._stats = dict(mem_hits=0, disk_hits=0, misses=0, puts=0,
                           evictions=0, disk_errors=0, quarantined=0)
        # plan access counts (fingerprint → key → lookups), persisted
        # beside the on-disk plan tier so the next process knows which
        # plans are hot before it has served a single query
        self._access: Dict[str, Dict[PlanKey, int]] = {}
        self._access_dirty = False
        self._access_flushed = 0.0
        # measured kernel tunings, keyed like plans but with the
        # requesting KernelSpec folded into the PlanKey
        self._tunings: Dict[Tuple[str, PlanKey], dict] = {}
        if self.cache_dir:
            self._load_access_log()
            self._load_tunings()

    # -- lookup ----------------------------------------------------------

    def get(self, fingerprint: str, key: PlanKey) -> Optional[Prepared]:
        self._record_access(fingerprint, key)
        with self._lock:
            ent = self._mem.get((fingerprint, key))
            if ent is not None:
                self._mem.move_to_end((fingerprint, key))
                self._stats["mem_hits"] += 1
                return ent[0]
        # disk deserialize happens OUTSIDE the lock: a multi-hundred-MB
        # plan load must not stall concurrent memory-tier hits
        p = self._load_disk(fingerprint, key)
        with self._lock:
            ent = self._mem.get((fingerprint, key))
            if ent is not None:  # raced with another loader: prefer it
                self._mem.move_to_end((fingerprint, key))
                self._stats["mem_hits"] += 1
                return ent[0]
            if p is not None:
                self._stats["disk_hits"] += 1
                self._insert(fingerprint, key, p)
                return p
            self._stats["misses"] += 1
            return None

    def put(self, fingerprint: str, key: PlanKey, p: Prepared) -> None:
        path = payload = None
        if self.cache_dir:
            path = os.path.join(self.cache_dir,
                                _plan_filename(fingerprint, key))
            if not os.path.exists(path):
                payload = eng.serialize_prepared(p)  # outside the lock
        with self._lock:
            self._stats["puts"] += 1
            self._insert(fingerprint, key, p)
        if payload is not None:
            # disk tier is best-effort on write, like it is on read: a
            # full/read-only cache dir must not fail a query whose plan
            # is already good in memory
            try:
                resilience.fire("planstore.disk_write", path=path)
                tmp = f"{path}.tmp.{os.getpid()}.{threading.get_ident()}"
                with open(tmp, "wb") as f:
                    f.write(payload)
                os.replace(tmp, path)  # atomic vs concurrent readers
            except (OSError, resilience.FaultInjected):
                with self._lock:
                    self._stats["disk_errors"] += 1

    def __contains__(self, fp_key: Tuple[str, PlanKey]) -> bool:
        with self._lock:
            return fp_key in self._mem

    def peek(self, fingerprint: str, key: PlanKey) -> Optional[Prepared]:
        """Memory-tier lookup WITHOUT stats or access accounting — for
        cost estimation (``GraphService.wave_cost``) and other
        introspection that must not skew hit rates or the warming log."""
        with self._lock:
            ent = self._mem.get((fingerprint, key))
            return ent[0] if ent is not None else None

    # -- internals -------------------------------------------------------

    def _insert(self, fingerprint: str, key: PlanKey, p: Prepared) -> None:
        k = (fingerprint, key)
        if k in self._mem:
            self._bytes -= self._mem[k][1]
            del self._mem[k]
        nb = p.nbytes
        self._mem[k] = (p, nb)
        self._bytes += nb
        # never evict the entry just inserted: a single plan larger than
        # the whole budget must still be servable (the budget overshoots
        # by one plan rather than degrading to rebuild-per-query)
        while self._bytes > self.max_bytes and len(self._mem) > 1:
            _, (_, old_nb) = self._mem.popitem(last=False)
            self._bytes -= old_nb
            self._stats["evictions"] += 1

    def _load_disk(self, fingerprint: str,
                   key: PlanKey) -> Optional[Prepared]:
        if not self.cache_dir:
            return None
        path = os.path.join(self.cache_dir,
                            _plan_filename(fingerprint, key))
        if not os.path.exists(path):
            return None
        try:
            with open(path, "rb") as f:
                data = f.read()
            data = resilience.corrupt_bytes("planstore.disk_read", data,
                                            path=os.path.basename(path))
            return eng.deserialize_prepared(data)
        except eng.PlanIntegrityError as e:
            # checksum says the bytes rotted: keep the evidence aside,
            # rebuild the plan from source — a disk-tier entry is a
            # cache, never the only copy of anything
            self._quarantine(path, str(e))
            return None
        except (ValueError, OSError, KeyError, EOFError,
                zipfile.BadZipFile):
            # stale format / truncated write: drop and rebuild
            try:
                os.remove(path)
            except OSError:
                pass
            return None

    def _quarantine(self, path: str, reason: str) -> None:
        """Move a corrupt cache file into ``quarantine/`` (best-effort:
        falls back to deletion), count it, and warn — the live path
        starts fresh either way."""
        qdir = os.path.join(self.cache_dir, QUARANTINE_DIR)
        moved = os.path.join(qdir, f"{os.path.basename(path)}."
                             f"{os.getpid()}")
        try:
            os.makedirs(qdir, exist_ok=True)
            os.replace(path, moved)
        except OSError:
            try:
                os.remove(path)
            except OSError:
                pass
        with self._lock:
            self._stats["quarantined"] += 1
        warnings.warn(
            f"quarantined corrupt plan-store file "
            f"{os.path.basename(path)!r}: {reason}", RuntimeWarning,
            stacklevel=3)

    # -- measured kernel tunings (autotune records) -----------------------

    def get_tuning(self, fingerprint: str, key: PlanKey) -> Optional[dict]:
        with self._lock:
            return self._tunings.get((fingerprint, key))

    def put_tuning(self, fingerprint: str, key: PlanKey,
                   record: dict) -> None:
        with self._lock:
            self._tunings[(fingerprint, key)] = dict(record)
        self._flush_tunings()

    def _flush_tunings(self) -> None:
        if not self.cache_dir:
            return
        with self._lock:
            body = [[fp, _key_to_json(k), rec]
                    for (fp, k), rec in self._tunings.items()]
        doc = {"version": 2, "tunings": body,
               "checksum": _json_checksum(body)}
        path = os.path.join(self.cache_dir, TUNINGS_LOG)
        try:
            tmp = f"{path}.tmp.{os.getpid()}.{threading.get_ident()}"
            with open(tmp, "w") as f:
                json.dump(doc, f)
            os.replace(tmp, path)  # atomic vs concurrent readers
        except OSError:
            with self._lock:
                self._stats["disk_errors"] += 1

    def _load_tunings(self) -> None:
        path = os.path.join(self.cache_dir, TUNINGS_LOG)
        if not os.path.exists(path):
            return
        try:
            with open(path) as f:
                doc = json.load(f)
            self._check_sidecar(doc, "tunings", (1, 2))
            self._tunings = {
                (fp, _key_from_json(kd)): rec
                for fp, kd, rec in doc.get("tunings", [])}
        except (OSError, ValueError, TypeError, KeyError) as e:
            # a corrupt tunings log only costs a re-measure — warn,
            # quarantine the file, start fresh (never raise from the
            # store constructor)
            self._quarantine(path, f"{type(e).__name__}: {e}")
            self._tunings = {}

    @staticmethod
    def _check_sidecar(doc: dict, body_key: str, versions: tuple) -> None:
        """Validate a JSON sidecar log: known version, and (v2+) the
        body matches its recorded checksum.  Raises ValueError —
        callers quarantine and start fresh."""
        v = doc.get("version")
        if v not in versions:
            raise ValueError(f"unknown {body_key} log version {v!r}")
        if v >= 2 and doc.get("checksum") != _json_checksum(
                doc.get(body_key, [] if body_key == "tunings" else {})):
            raise ValueError(f"{body_key} log checksum mismatch")

    # -- plan access log (feeds serve.server plan warming) ---------------

    def _record_access(self, fingerprint: str, key: PlanKey) -> None:
        if not self.cache_dir:
            return   # no disk tier → nowhere to persist, nothing to warm
        with self._lock:
            per = self._access.setdefault(fingerprint, {})
            per[key] = per.get(key, 0) + 1
            self._access_dirty = True
            due = time.monotonic() - self._access_flushed >= _ACCESS_FLUSH_S
        if due:
            self.flush_access_log()

    def hot_keys(self, fingerprint: str,
                 limit: Optional[int] = None) -> List[PlanKey]:
        """A graph's plans, most-requested first — what ``register()``
        should speculatively prepare before traffic arrives."""
        with self._lock:
            per = sorted(self._access.get(fingerprint, {}).items(),
                         key=lambda kv: (-kv[1], repr(kv[0])))
        keys = [k for k, _ in per]
        return keys[:limit] if limit is not None else keys

    def flush_access_log(self) -> None:
        """Persist access counts (best-effort, atomic, throttled by the
        callers; explicit so servers can flush on close)."""
        if not self.cache_dir:
            return
        with self._lock:
            if not self._access_dirty:
                return
            body = {fp: [[_key_to_json(k), c] for k, c in per.items()]
                    for fp, per in self._access.items()}
            doc = {"version": 2, "graphs": body,
                   "checksum": _json_checksum(body)}
            self._access_dirty = False
            self._access_flushed = time.monotonic()
        path = os.path.join(self.cache_dir, ACCESS_LOG)
        try:
            tmp = f"{path}.tmp.{os.getpid()}.{threading.get_ident()}"
            with open(tmp, "w") as f:
                json.dump(doc, f)
            os.replace(tmp, path)
        except OSError:
            with self._lock:
                self._stats["disk_errors"] += 1

    def _load_access_log(self) -> None:
        path = os.path.join(self.cache_dir, ACCESS_LOG)
        if not os.path.exists(path):
            return
        try:
            with open(path) as f:
                doc = json.load(f)
            self._check_sidecar(doc, "graphs", (1, 2))
            self._access = {
                fp: {_key_from_json(kd): int(c) for kd, c in per}
                for fp, per in doc.get("graphs", {}).items()}
        except (OSError, ValueError, TypeError, KeyError) as e:
            # a corrupt log only costs warming, never correctness
            self._quarantine(path, f"{type(e).__name__}: {e}")
            self._access = {}

    # -- introspection ---------------------------------------------------

    def keys(self) -> List[Tuple[str, PlanKey]]:
        with self._lock:
            return list(self._mem)

    @property
    def nbytes(self) -> int:
        return self._bytes

    def stats(self) -> dict:
        with self._lock:
            s = dict(self._stats, plans=len(self._mem),
                     bytes=self._bytes, max_bytes=self.max_bytes,
                     tunings=len(self._tunings))
            lookups = s["mem_hits"] + s["disk_hits"] + s["misses"]
            # per-tier rates: a memory hit is free, a disk hit still
            # pays a deserialize — capacity tuning needs to see both
            s["mem_hit_rate"] = s["mem_hits"] / lookups if lookups \
                else 0.0
            s["disk_hit_rate"] = s["disk_hits"] / lookups if lookups \
                else 0.0
            s["hit_rate"] = s["mem_hit_rate"] + s["disk_hit_rate"]
            return s


@dataclasses.dataclass
class _Pending:
    ticket: int
    name: str
    spec: QuerySpec


class GraphService:
    """Multi-graph serving gateway: registry + shared plan store + a
    coalescing request front door.

    All registered graphs borrow plans from one ``PlanStore`` (one byte
    budget, one eviction policy, one persistence path), so the service —
    not each session — owns the memory/rebuild trade-off.
    """

    def __init__(self, max_plan_bytes: int = 256 << 20,
                 cache_dir: Optional[str] = None,
                 policy: Optional[ExecutionPolicy] = None,
                 max_wave: int = 64):
        self.store = PlanStore(max_bytes=max_plan_bytes,
                               cache_dir=cache_dir)
        self.policy = policy
        self.max_wave = int(max_wave)
        self._procs: Dict[str, GraphProcessor] = {}
        self._pending: List[_Pending] = []
        self._dead: Dict[int, Exception] = {}  # tickets killed by evict()
        self._next_ticket = 0
        self._lock = threading.RLock()
        self._coalesced_queries = 0
        self._batched_runs = 0
        self._degraded_runs = 0

    # -- graph registry --------------------------------------------------

    def register(self, name: str, g: Graph, b: int = 32,
                 num_clusters: Optional[int] = None,
                 clustered: bool = True, seed: int = 0,
                 policy: Optional[ExecutionPolicy] = None
                 ) -> GraphProcessor:
        """Admit a graph under ``name``; returns its processor.

        Re-registering the same name with the identical graph AND
        identical session parameters is a no-op (idempotent restarts);
        any difference — graph contents, tiling, clustering knobs,
        default policy — under a live name is an error: ``evict`` first.
        """
        with self._lock:
            if name in self._procs:
                old = self._procs[name]
                same = (old.g.fingerprint() == g.fingerprint()
                        and (old.b, old.num_clusters, old.clustered,
                             old.seed) == (b, num_clusters, clustered,
                                           seed)
                        and old.policy == (policy or self.policy
                                           or ExecutionPolicy()))
                if same:
                    return old
                raise ValueError(
                    f"graph name {name!r} is already registered with "
                    "different contents or session parameters; "
                    "evict() it first")
            proc = GraphProcessor(
                g, b=b, num_clusters=num_clusters, clustered=clustered,
                seed=seed, policy=policy or self.policy,
                store=self.store)
            self._procs[name] = proc
            return proc

    def get(self, name: str) -> GraphProcessor:
        try:
            return self._procs[name]
        except KeyError:
            raise KeyError(
                f"no graph registered as {name!r}; have "
                f"{sorted(self._procs)}") from None

    def evict(self, name: str) -> None:
        """Drop a graph from the registry.  Its plans stay in the store
        (and on disk) until LRU pressure reclaims them — re-registering
        the same graph later warm-starts.  Pending tickets for the graph
        are not lost: the next ``gather`` resolves them to a KeyError."""
        with self._lock:
            self._procs.pop(name, None)
            keep = []
            for q in self._pending:
                if q.name == name:
                    self._dead[q.ticket] = KeyError(
                        f"graph {name!r} was evicted before the query "
                        "ran")
                else:
                    keep.append(q)
            self._pending = keep

    def graphs(self) -> List[str]:
        return sorted(self._procs)

    def __contains__(self, name: str) -> bool:
        return name in self._procs

    # -- direct execution ------------------------------------------------

    def run(self, name: str, spec: QuerySpec) -> Result:
        return self._note_result(self.get(name).run(spec))

    def _note_result(self, res: Result) -> Result:
        """Service-level accounting on a completed run (degradation
        ladder outcomes — ``stats()['degraded_runs']``)."""
        if "degraded" in res.extra:
            with self._lock:
                self._degraded_runs += 1
        return res

    def wave_cost(self, name: str, algo: str, pol: ExecutionPolicy,
                  rows: int = 1) -> float:
        """Relative cost estimate for one wave: plan tiles × sweep bound
        × rows.  Uses the cached plan when one is resident (``peek`` —
        no store-stats noise), else falls back to the graph's nnz.  The
        scheduler's watchdog scales its per-wave deadline by this, so
        big graphs aren't reaped on the schedule of small ones."""
        proc = self.get(name)
        a = get_algorithm(algo)
        pk = proc.plan_key(a.semiring, variant=a.variant, pull=a.pull,
                           normalize=a.normalize)
        p = self.store.peek(proc.g.fingerprint(), pk)
        tiles = float(p.tiles_total) if p is not None \
            else float(proc.g.nnz)
        return tiles * max(int(pol.max_sweeps), 1) * max(int(rows), 1)

    # -- coalescing front door -------------------------------------------

    def wave_key(self, name: str, spec: QuerySpec) -> Optional[tuple]:
        """Validate a request and resolve its coalescing key.

        Raises ``KeyError`` for unregistered names and ``ValueError``/
        ``TypeError`` for specs that can never execute — at *submit*
        time, so a bad request cannot poison the batch it would have
        ridden in.  Returns ``(name, algo, resolved_policy)`` when the
        request can share a batched wave (single-source queries of an
        algorithm whose ``AlgorithmSpec.coalescible`` is set — same key
        ⇒ same plan ⇒ same wave), else ``None`` (run individually).
        Shared by ``submit``/``gather`` and the background scheduler
        (``serve.sched.WaveScheduler``) so both front doors group
        requests exactly as ``run`` would execute them.
        """
        proc = self.get(name)  # fail fast on unknown graphs
        validate_spec(spec)
        pol = proc.resolve_policy(spec)  # surfaces bad params/fields
        if (get_algorithm(spec.algo).coalescible and not spec.batched
                and len(spec.sources) == 1):
            return (name, spec.algo, pol)
        return None

    def submit(self, name: str, spec: QuerySpec) -> int:
        """Enqueue one query; returns a ticket for ``gather``.

        Invalid requests are rejected here, not at ``gather`` — a bad
        spec must not poison the batch it would have ridden in.
        """
        self.wave_key(name, spec)
        with self._lock:
            t = self._next_ticket
            self._next_ticket += 1
            self._pending.append(_Pending(t, name, spec))
            return t

    def gather(self) -> Dict[int, Union[Result, Exception]]:
        """Run everything pending and return ``{ticket: Result}``.

        Single-source requests of coalescible algorithms that resolve to
        the same
        (graph, algorithm, policy) — hence the same plan — are coalesced
        into batched runs of up to ``max_wave`` sources (waves, as in
        ``ServeLoop``); each ticket gets its own row of the batch.  The
        wave executes on whatever engine the resolved policy names: vmap
        over the sync/async engines, or — for ``mode="distributed"`` —
        ONE 2-D ``("graph", "query")`` shard_map dispatch
        (``placement.distributed_sync_run_batched``, or the self-timed
        ``async_dist.distributed_async_run_batched`` when the policy says
        ``dist_flavor="async"``), so a distributed plan's wave scales
        over both mesh axes instead of looping per source.  Per-query convergence is masked in all engines, so
        coalesced values are identical to what sequential ``run`` calls
        produce.  Everything else (PageRank, CC, already-batched specs,
        …) runs individually.

        A query that fails at run time — or whose graph was ``evict``-ed
        while it waited — maps its ticket(s) to the raised exception
        instead of a ``Result``: every issued ticket resolves, and one
        bad request never drops the other tickets in the batch.

        Note: a coalesced ticket's ``Result.stats`` is the WAVE's
        aggregate (work counters total the whole batch; ``sweeps`` is
        the straggler's) — per-ticket only the ``values`` row is
        sliced.  ``extra["coalesced"]`` carries the wave size so
        downstream accounting can tell shared stats from per-query
        ones.
        """
        with self._lock:
            pending, self._pending = self._pending, []
            dead, self._dead = self._dead, {}
        results: Dict[int, Union[Result, Exception]] = dict(dead)
        waves: Dict[tuple, List[_Pending]] = collections.OrderedDict()
        for q in pending:
            try:
                key = self.wave_key(q.name, q.spec)
            except Exception as e:  # may race a concurrent evict()
                results[q.ticket] = e
                continue
            if key is not None:
                waves.setdefault(key, []).append(q)
            else:
                try:
                    results[q.ticket] = self.get(q.name).run(q.spec)
                except Exception as e:  # keep serving the rest
                    results[q.ticket] = e
        for (name, algo, pol), group in waves.items():
            results.update(self._run_wave(name, algo, pol, group))
        return results

    def _run_wave(self, name: str, algo: str, pol: ExecutionPolicy,
                  group: List[_Pending]
                  ) -> Dict[int, Union[Result, Exception]]:
        """Execute one coalescible group (same ``wave_key``) and map
        every ticket to its Result or Exception.

        Chunks the group into waves of at most ``max_wave`` sources and
        runs each as ONE batched dispatch, slicing per-ticket rows out —
        the engine-facing half of ``gather``, factored out so the
        background continuous-batching scheduler
        (``serve.sched.WaveScheduler``) shares the exact same execution
        path.  Thread-safe: plan lookups go through the locked
        ``PlanStore``, engine dispatch holds no service state, and the
        wave counters take ``_lock`` — concurrent callers (a ``gather``
        racing the scheduler thread) at worst build a plan twice, never
        corrupt one.
        """
        results: Dict[int, Union[Result, Exception]] = {}
        try:
            proc = self.get(name)
        except KeyError as e:  # evicted while the group waited
            return {q.ticket: e for q in group}
        for i in range(0, len(group), self.max_wave):
            wave = group[i:i + self.max_wave]
            try:
                if len(wave) == 1:
                    q = wave[0]
                    results[q.ticket] = self._note_result(
                        proc.run(q.spec))
                    continue
                sources = tuple(q.spec.sources[0] for q in wave)
                batch = self._note_result(
                    proc.run(QuerySpec(algo=algo, sources=sources,
                                       batched=True, policy=pol)))
            except Exception as e:
                for q in wave:
                    results[q.ticket] = e
                continue
            with self._lock:
                self._coalesced_queries += len(wave)
                self._batched_runs += 1
            for row, q in enumerate(wave):
                extra = {"algo": algo, "src": sources[row],
                         "coalesced": len(wave)}
                for k in ("dist", "batched_fallback", "degraded"):
                    # distributed waves: surface the engine's mesh
                    # factorization / per-query sweeps per ticket
                    if k in batch.extra:
                        extra[k] = batch.extra[k]
                if "dist" in batch.extra:
                    # which exchange schedule actually served the wave
                    extra["dist_flavor"] = pol.dist_flavor
                results[q.ticket] = Result(
                    np.asarray(batch.values[row]), batch.stats,
                    batch.prepared, extra, policy=pol,
                    graph=proc.g)
        return results

    # -- introspection ---------------------------------------------------

    def stats(self) -> dict:
        with self._lock:
            return {"graphs": self.graphs(),
                    "pending": len(self._pending),
                    "coalesced_queries": self._coalesced_queries,
                    "batched_runs": self._batched_runs,
                    "degraded_runs": self._degraded_runs,
                    "plan_store": self.store.stats()}
