"""Deterministic fault injection + the transient-error taxonomy.

The paper's architecture argument is that a self-timed array keeps
making progress at each element's *actual* local behavior instead of
stalling on the global worst case.  The serving stack earns that claim
only if it survives the failure modes a real fleet produces: corrupt
plan bytes on disk, a kernel dispatch that dies at trace time, a
straggling or failed shard exchange, a wave dispatch that hangs.  This
module makes those failures *reproducible* so the recovery machinery
(scheduler retries, the ``ExecutionPolicy`` degradation ladder,
``PlanStore`` quarantine, the wave watchdog) is tested against the
exact events it claims to absorb.

Usage::

    from repro import resilience as rz
    plan = rz.FaultPlan([rz.FaultSpec("kernel.select", count=1,
                                      where={"impl": "pallas"})], seed=7)
    with rz.inject(plan):
        res = proc.sssp(0)          # first pallas dispatch fails,
                                    # the ladder retries with ref
    plan.stats()                    # {"kernel.select": {...}}

Design rules:

  * **Off by default, zero overhead when disabled.**  Every hook first
    reads one module global; with no plan installed that is the whole
    cost.  No site changes work counters, so modeled benchmark numbers
    (``BENCH_graph.json``) are bit-identical with injection disabled.
  * **Deterministic.**  A ``FaultPlan`` owns one seeded RNG; given the
    same seed and the same call sequence it injects at the same hooks.
  * **Sites are host-level.**  Hooks live in Python dispatch/IO code
    (trace time for jitted engines), never inside compiled kernels —
    injection must not perturb the compiled program itself.

Registered sites (``SITES``):

  planstore.disk_read    corrupt the plan payload bytes after a disk
                         read (``mode="corrupt"``) — exercises the
                         checksum + quarantine path
  planstore.disk_write   fail the best-effort disk write
                         (``exc="oserror"`` keeps the store's
                         best-effort contract observable)
  kernel.select          raise at ``kernels.ops.select_kernel`` —
                         kernel dispatch/trace failure; ctx carries
                         ``op``/``impl``/``fused`` for targeting
  engine.run             raise at the local engine entry points
                         (``run_sync``/``run_async`` and batched)
  dist.dispatch          raise at the distributed engines' host entry —
                         a failed exchange round; ctx carries
                         ``flavor``/``batched``
  dist.straggler         sleep at the distributed engines' host entry —
                         a straggling shard delaying the whole dispatch
  sched.dispatch         raise or sleep inside ``WaveScheduler``'s wave
                         dispatch — a crashed or hung wave (the sleep
                         form is what the watchdog reaps)
"""

from __future__ import annotations

import contextlib
import dataclasses
import random
import threading
import time
from typing import Dict, Iterable, Optional, Tuple, Union

SITES = (
    "planstore.disk_read",
    "planstore.disk_write",
    "kernel.select",
    "engine.run",
    "dist.dispatch",
    "dist.straggler",
    "sched.dispatch",
)


class Transient:
    """Marker mixin: errors that MAY succeed on retry (an injected
    fault, a wave that outlived its watchdog).  The scheduler's retry
    budget applies only to these — a deterministic error (bad spec,
    missing kernel registration) re-raised N times is just N times the
    latency for the same failure."""


class FaultInjected(Transient, RuntimeError):
    """An injected fault fired at a named site (see ``FaultPlan``)."""


def is_transient(exc: BaseException) -> bool:
    return isinstance(exc, Transient)


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One injection rule.

    site:     a ``SITES`` name.
    mode:     "raise" (default) | "delay" (sleep ``delay_s``) |
              "corrupt" (mangle the bytes at a data site).
    p:        injection probability per matching hit (plan-seeded RNG).
    count:    stop after this many injections (None = unlimited).
    after:    skip this many matching hits before injecting.
    delay_s:  sleep length for ``mode="delay"``.
    exc:      "fault" raises ``FaultInjected``; "oserror" raises
              ``OSError`` (for sites whose real-world failure is IO,
              e.g. ``planstore.disk_write``).
    where:    context filter — only hits whose ctx matches every
              (key, value) pair are eligible; a dict is accepted and
              frozen to sorted items.
    """

    site: str
    mode: str = "raise"
    p: float = 1.0
    count: Optional[int] = None
    after: int = 0
    delay_s: float = 0.05
    exc: str = "fault"
    where: Union[Dict[str, object], Tuple[Tuple[str, object], ...]] = ()

    def __post_init__(self):
        if self.site not in SITES:
            raise ValueError(
                f"unknown fault site {self.site!r}; have {SITES}")
        if self.mode not in ("raise", "delay", "corrupt"):
            raise ValueError(f"mode must be raise|delay|corrupt: "
                             f"{self.mode!r}")
        if self.exc not in ("fault", "oserror"):
            raise ValueError(f"exc must be fault|oserror: {self.exc!r}")
        if not 0.0 <= self.p <= 1.0:
            raise ValueError(f"p must be in [0, 1]: {self.p!r}")
        if isinstance(self.where, dict):
            object.__setattr__(
                self, "where", tuple(sorted(self.where.items())))

    def matches(self, ctx: dict) -> bool:
        return all(ctx.get(k) == v for k, v in self.where)


class FaultPlan:
    """A seeded set of ``FaultSpec`` rules plus per-site accounting.

    Thread-safe: hooks fire from scheduler workers, warm threads, and
    client threads concurrently.  ``stats()`` reports, per site, how
    many hook hits matched a rule and how many actually injected —
    the observability half of the acceptance story ("every submitted
    request resolves AND the faults really happened").
    """

    def __init__(self, specs: Iterable[FaultSpec], seed: int = 0):
        self.specs = tuple(specs)
        self.seed = int(seed)
        self._rng = random.Random(f"repro-faults:{self.seed}")
        self._lock = threading.Lock()
        self._hits: Dict[str, int] = {}
        self._injected: Dict[str, int] = {}
        self._spec_hits = [0] * len(self.specs)
        self._spec_fired = [0] * len(self.specs)

    def _arm(self, site: str, ctx: dict, modes: Tuple[str, ...]
             ) -> Optional[FaultSpec]:
        """The spec that should inject at this hit, or None (counts
        either way)."""
        with self._lock:
            self._hits[site] = self._hits.get(site, 0) + 1
            for i, s in enumerate(self.specs):
                if (s.site != site or s.mode not in modes
                        or not s.matches(ctx)):
                    continue
                self._spec_hits[i] += 1
                if self._spec_hits[i] <= s.after:
                    continue
                if s.count is not None and self._spec_fired[i] >= s.count:
                    continue
                if s.p < 1.0 and self._rng.random() >= s.p:
                    continue
                self._spec_fired[i] += 1
                self._injected[site] = self._injected.get(site, 0) + 1
                return s
        return None

    def stats(self) -> Dict[str, Dict[str, int]]:
        with self._lock:
            sites = set(self._hits) | set(self._injected)
            return {s: {"hits": self._hits.get(s, 0),
                        "injected": self._injected.get(s, 0)}
                    for s in sorted(sites)}


# the active plan: one module global so the disabled fast path is a
# single attribute read at every hook
_ACTIVE: Optional[FaultPlan] = None
_INSTALL_LOCK = threading.Lock()


def install(plan: FaultPlan) -> None:
    global _ACTIVE
    with _INSTALL_LOCK:
        if _ACTIVE is not None:
            raise RuntimeError("a FaultPlan is already installed; "
                               "uninstall() it first")
        _ACTIVE = plan


def uninstall() -> None:
    global _ACTIVE
    with _INSTALL_LOCK:
        _ACTIVE = None


def active() -> Optional[FaultPlan]:
    return _ACTIVE


@contextlib.contextmanager
def inject(plan: FaultPlan):
    """``with rz.inject(plan): ...`` — install for the block, always
    uninstall after (also on exceptions, which injection produces by
    design)."""
    install(plan)
    try:
        yield plan
    finally:
        uninstall()


def fire(site: str, **ctx) -> None:
    """Raise/sleep hook.  No-op (one global read) with no plan active."""
    plan = _ACTIVE
    if plan is None:
        return
    spec = plan._arm(site, ctx, ("raise", "delay"))
    if spec is None:
        return
    if spec.mode == "delay":
        time.sleep(spec.delay_s)
        return
    msg = f"injected fault at {site}" + (f" {ctx}" if ctx else "")
    if spec.exc == "oserror":
        raise OSError(msg)
    raise FaultInjected(msg)


def corrupt_bytes(site: str, data: bytes, **ctx) -> bytes:
    """Data-corruption hook: returns ``data`` with one byte flipped when
    a ``mode="corrupt"`` rule fires, else ``data`` unchanged."""
    plan = _ACTIVE
    if plan is None:
        return data
    spec = plan._arm(site, ctx, ("corrupt",))
    if spec is None or not data:
        return data
    # flip a byte in the back half: headers/magic survive, so the
    # corruption is caught by the checksum, not by format parsing
    pos = len(data) // 2 + len(data) // 4
    return data[:pos] + bytes([data[pos] ^ 0xFF]) + data[pos + 1:]
