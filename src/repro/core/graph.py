"""Graph containers and synthetic workload generators.

Host-side (numpy) preprocessing mirrors the paper's compile-time flow: the
application graph is profiled/extracted once, then clustered, placed and
compiled (see ``cluster.py`` / ``compile.py``).  Device-side formats are
static-shape and TPU-friendly:

  * ``EllGraph``  — padded adjacency (row-major ELL), for neighbour-list
    algorithms (MiniTri intersections, DFS).
  * ``BsrGraph``  — ELL-of-dense-tiles block-sparse format produced by the
    clustering/reorder pass; the unit of NALE work is one BxB tile.

The paper evaluates on three graphs: CA road network, Facebook, LiveJournal.
Those datasets are not available offline, so ``road_network`` (grid +
shortcuts, avg degree ~1.4 directed) and ``rmat`` (power-law, FB/LJ-like)
generate stand-ins with matched vertex/edge statistics at configurable
scale.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Optional

import numpy as np

from . import semiring as sr


@dataclasses.dataclass
class Graph:
    """Host-side CSR graph.  ``indptr``/``indices`` int64/int32 numpy."""

    n: int
    indptr: np.ndarray  # (n+1,)
    indices: np.ndarray  # (nnz,)
    weights: np.ndarray  # (nnz,) float32

    @property
    def nnz(self) -> int:
        return int(self.indices.shape[0])

    @property
    def avg_degree(self) -> float:
        return self.nnz / max(self.n, 1)

    def out_degrees(self) -> np.ndarray:
        return np.diff(self.indptr)

    @staticmethod
    def from_edges(n: int, src: np.ndarray, dst: np.ndarray,
                   weights: Optional[np.ndarray] = None,
                   dedup: bool = True) -> "Graph":
        if weights is None:
            weights = np.ones_like(src, dtype=np.float32)
        if dedup and len(src):
            key = src.astype(np.int64) * n + dst.astype(np.int64)
            _, keep = np.unique(key, return_index=True)
            src, dst, weights = src[keep], dst[keep], weights[keep]
        order = np.lexsort((dst, src))
        src, dst, weights = src[order], dst[order], weights[order]
        indptr = np.zeros(n + 1, dtype=np.int64)
        np.add.at(indptr, src + 1, 1)
        indptr = np.cumsum(indptr)
        return Graph(n=n, indptr=indptr, indices=dst.astype(np.int32),
                     weights=weights.astype(np.float32))

    def transpose(self) -> "Graph":
        src = np.repeat(np.arange(self.n, dtype=np.int32),
                        np.diff(self.indptr))
        return Graph.from_edges(self.n, self.indices.astype(np.int32),
                                src, self.weights, dedup=False)

    def to_undirected(self) -> "Graph":
        src = np.repeat(np.arange(self.n, dtype=np.int32),
                        np.diff(self.indptr))
        dst = self.indices.astype(np.int32)
        s = np.concatenate([src, dst])
        d = np.concatenate([dst, src])
        w = np.concatenate([self.weights, self.weights])
        return Graph.from_edges(self.n, s, d, w, dedup=True)

    def permute(self, perm: np.ndarray) -> "Graph":
        """Relabel vertices: new id of old vertex v is perm[v]."""
        src = np.repeat(np.arange(self.n, dtype=np.int32),
                        np.diff(self.indptr))
        return Graph.from_edges(self.n, perm[src].astype(np.int32),
                                perm[self.indices].astype(np.int32),
                                self.weights, dedup=False)

    def fingerprint(self) -> str:
        """Stable content hash of the graph (topology + weights).

        Used as the graph half of cross-process plan-store keys
        (``serve.graph.PlanStore``): two Graph objects with identical
        structure hash identically, so a restarted service can find the
        plans a previous process persisted.  Graphs are treated as
        immutable after construction; the digest is cached.
        """
        fp = self.__dict__.get("_fingerprint")
        if fp is None:
            h = hashlib.blake2b(digest_size=16)
            h.update(np.int64(self.n).tobytes())
            h.update(np.ascontiguousarray(self.indptr,
                                          dtype=np.int64).tobytes())
            h.update(np.ascontiguousarray(self.indices,
                                          dtype=np.int32).tobytes())
            h.update(np.ascontiguousarray(self.weights,
                                          dtype=np.float32).tobytes())
            fp = self.__dict__["_fingerprint"] = h.hexdigest()
        return fp


# ---------------------------------------------------------------------------
# Device-side formats
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class EllGraph:
    """Padded neighbour lists: (n, k_max) arrays; pad col = n (sentinel)."""

    n: int
    k_max: int
    cols: np.ndarray    # (n, k_max) int32, padded with n
    vals: np.ndarray    # (n, k_max) float32, padded with pad_val
    deg: np.ndarray     # (n,) int32


def to_ell(g: Graph, pad_val: float = 0.0,
           k_max: Optional[int] = None) -> EllGraph:
    deg = np.diff(g.indptr).astype(np.int32)
    k = int(deg.max()) if k_max is None and g.n else (k_max or 1)
    k = max(k, 1)
    cols = np.full((g.n, k), g.n, dtype=np.int32)
    vals = np.full((g.n, k), pad_val, dtype=np.float32)
    for i in range(g.n):  # host-side, one-time preprocessing
        s, e = g.indptr[i], g.indptr[i + 1]
        cols[i, : e - s] = g.indices[s:e]
        vals[i, : e - s] = g.weights[s:e]
    return EllGraph(n=g.n, k_max=k, cols=cols, vals=vals, deg=deg)


def to_ell_fast(g: Graph, pad_val: float = 0.0) -> EllGraph:
    """Vectorized ELL conversion (no per-row python loop)."""
    deg = np.diff(g.indptr).astype(np.int32)
    k = max(int(deg.max()) if g.n else 1, 1)
    cols = np.full((g.n, k), g.n, dtype=np.int32)
    vals = np.full((g.n, k), pad_val, dtype=np.float32)
    rows = np.repeat(np.arange(g.n), deg)
    offs = np.arange(g.nnz) - np.repeat(g.indptr[:-1], deg)
    cols[rows, offs] = g.indices
    vals[rows, offs] = g.weights
    return EllGraph(n=g.n, k_max=k, cols=cols, vals=vals, deg=deg)


@dataclasses.dataclass
class BsrGraph:
    """ELL-of-tiles block-sparse matrix (the NALE work-unit container).

    Row-blocks of size ``b``; for row-block r, up to ``k_max`` nonempty
    column tiles.  Padding tiles point at col-block 0 and hold the
    semiring's ⊕-identity so they are arithmetic no-ops (the hardware
    analogue: an empty FIFO slot).
    """

    n: int              # logical vertex count (pre-padding)
    b: int              # tile edge size
    r: int              # number of row/col blocks  (n_pad / b)
    k_max: int          # max nonempty tiles per row-block
    block_cols: np.ndarray   # (r, k_max) int32
    block_vals: np.ndarray   # (r, k_max, b, b) float32
    block_nnz: np.ndarray    # (r,) int32 — nonempty tile count per row-block
    edge_nnz: np.ndarray     # (r,) int64 — true edge count per row-block
    pad_value: float

    @property
    def n_pad(self) -> int:
        return self.r * self.b

    @property
    def tiles(self) -> int:
        return int(self.block_nnz.sum())

    def density_stats(self) -> dict:
        """Tile fill statistics — measures how well clustering densified."""
        edges = float(self.edge_nnz.sum())
        tiles = max(self.tiles, 1)
        return {
            "tiles": self.tiles,
            "edges": edges,
            "fill": edges / (tiles * self.b * self.b),
            "tiles_per_rowblock_max": int(self.block_nnz.max()) if self.r else 0,
            "tiles_per_rowblock_mean": float(self.block_nnz.mean()) if self.r else 0.0,
        }


def to_bsr(g: Graph, b: int, pad_value: float = 0.0,
           semiring_name: str = "plus_times") -> BsrGraph:
    """Convert CSR → block-sparse tiles.  Use after cluster-reordering.

    ``pad_value`` must be the ⊕-identity of the target semiring so that
    padded tiles / absent intra-tile edges contribute nothing (for
    plus_times: 0; min_plus: +inf; max_min: 0).
    """
    pad_value = float(sr.get(semiring_name).zero) if pad_value is None else pad_value
    r = (g.n + b - 1) // b
    src = np.repeat(np.arange(g.n, dtype=np.int64), np.diff(g.indptr))
    dst = g.indices.astype(np.int64)
    rb, cb = src // b, dst // b
    tile_key = rb * r + cb
    uniq, tile_of_edge = np.unique(tile_key, return_inverse=True)
    u_rb, u_cb = uniq // r, uniq % r
    # tiles per row-block
    block_nnz = np.zeros(r, dtype=np.int32)
    np.add.at(block_nnz, u_rb, 1)
    k_max = max(int(block_nnz.max()) if len(uniq) else 1, 1)
    block_cols = np.zeros((r, k_max), dtype=np.int32)
    block_vals = np.full((r, k_max, b, b), pad_value, dtype=np.float32)
    # slot of each unique tile within its row-block (uniq is sorted by key,
    # hence grouped by rb in order)
    first_idx = np.searchsorted(u_rb, np.arange(r))
    slot = np.arange(len(uniq)) - first_idx[u_rb]
    block_cols[u_rb, slot] = u_cb.astype(np.int32)
    # scatter edge values into their tile
    e_slot = slot[tile_of_edge]
    block_vals[rb, e_slot, src % b, dst % b] = g.weights
    edge_nnz = np.zeros(r, dtype=np.int64)
    np.add.at(edge_nnz, rb, 1)
    return BsrGraph(n=g.n, b=b, r=r, k_max=k_max, block_cols=block_cols,
                    block_vals=block_vals, block_nnz=block_nnz,
                    edge_nnz=edge_nnz, pad_value=pad_value)


def bsr_to_dense(bsr: BsrGraph) -> np.ndarray:
    """Oracle-side densification (small graphs only)."""
    a = np.full((bsr.n_pad, bsr.n_pad), bsr.pad_value, dtype=np.float32)
    for rb in range(bsr.r):
        for k in range(int(bsr.block_nnz[rb])):
            cb = int(bsr.block_cols[rb, k])
            tile = bsr.block_vals[rb, k]
            cur = a[rb * bsr.b:(rb + 1) * bsr.b, cb * bsr.b:(cb + 1) * bsr.b]
            if bsr.pad_value == 0.0:
                a[rb * bsr.b:(rb + 1) * bsr.b,
                  cb * bsr.b:(cb + 1) * bsr.b] = cur + tile
            else:
                a[rb * bsr.b:(rb + 1) * bsr.b,
                  cb * bsr.b:(cb + 1) * bsr.b] = np.minimum(cur, tile)
    return a


# ---------------------------------------------------------------------------
# Synthetic workloads (paper §III stand-ins)
# ---------------------------------------------------------------------------


def rmat(n: int, nnz: int, seed: int = 0,
         a: float = 0.57, b: float = 0.19, c: float = 0.19,
         weighted: bool = True) -> Graph:
    """R-MAT power-law generator — Facebook/LiveJournal-like topology."""
    rng = np.random.default_rng(seed)
    scale = int(np.ceil(np.log2(max(n, 2))))
    n_pow = 1 << scale
    m = int(nnz * 1.15) + 16  # oversample; dedup trims
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    for level in range(scale):
        r = rng.random(m)
        quad = np.select(
            [r < a, r < a + b, r < a + b + c],
            [0, 1, 2], default=3)
        src = src * 2 + (quad >> 1)
        dst = dst * 2 + (quad & 1)
    keep = (src < n) & (dst < n) & (src != dst)
    src, dst = src[keep][:nnz], dst[keep][:nnz]
    w = (rng.random(len(src)).astype(np.float32) * 9 + 1) if weighted \
        else np.ones(len(src), dtype=np.float32)
    g = Graph.from_edges(n, src.astype(np.int32), dst.astype(np.int32), w)
    _ = n_pow
    return g


def road_network(side: int, seed: int = 0, extra_frac: float = 0.05,
                 weighted: bool = True) -> Graph:
    """Grid road network with sparse shortcuts — CA-road-like topology.

    A side×side lattice: avg out-degree ≈ 2 with lattice edges made
    directional at random (≈1.4 like CA road), plus a few long shortcuts
    (highways).
    """
    rng = np.random.default_rng(seed)
    n = side * side
    ii, jj = np.meshgrid(np.arange(side), np.arange(side), indexing="ij")
    vid = (ii * side + jj).ravel()
    right = vid.reshape(side, side)[:, :-1].ravel()
    down = vid.reshape(side, side)[:-1, :].ravel()
    src = np.concatenate([right, down])
    dst = np.concatenate([right + 1, down + side])
    # make ~70% of lattice edges one-way (matches CA avg degree ~1.4)
    fwd = rng.random(len(src)) < 0.7
    s = np.concatenate([src, dst[~fwd]])
    d = np.concatenate([dst, src[~fwd]])
    n_extra = int(extra_frac * n)
    es = rng.integers(0, n, n_extra)
    ed = rng.integers(0, n, n_extra)
    s = np.concatenate([s, es])
    d = np.concatenate([d, ed])
    keep = s != d
    s, d = s[keep], d[keep]
    w = (rng.random(len(s)).astype(np.float32) * 9 + 1) if weighted \
        else np.ones(len(s), dtype=np.float32)
    return Graph.from_edges(n, s.astype(np.int32), d.astype(np.int32), w)


def ring(n: int, weighted: bool = False) -> Graph:
    src = np.arange(n, dtype=np.int32)
    dst = (src + 1) % n
    w = np.ones(n, dtype=np.float32)
    return Graph.from_edges(n, src, dst, w)


def erdos(n: int, p: float, seed: int = 0, weighted: bool = True) -> Graph:
    rng = np.random.default_rng(seed)
    m = rng.random((n, n)) < p
    np.fill_diagonal(m, False)
    src, dst = np.nonzero(m)
    w = (rng.random(len(src)).astype(np.float32) * 9 + 1) if weighted \
        else np.ones(len(src), dtype=np.float32)
    return Graph.from_edges(n, src.astype(np.int32), dst.astype(np.int32), w)


# Paper workload registry: name -> (generator, full-scale stats for models)
# Full-scale numbers are the paper's:  (vertices, edges)
PAPER_GRAPHS = {
    "ca": dict(kind="road", vertices=1_965_206, edges=2_766_607, avg_deg=1.41),
    "fb": dict(kind="rmat", vertices=2_937_612, edges=41_919_708, avg_deg=14.3),
    "lj": dict(kind="rmat", vertices=4_847_571, edges=85_702_475, avg_deg=17.6),
}


def make_paper_graph(name: str, scale: float = 1.0 / 256, seed: int = 0) -> Graph:
    """Generate a stand-in for a paper graph at ``scale`` of full size."""
    spec = PAPER_GRAPHS[name]
    n = max(int(spec["vertices"] * scale), 64)
    e = max(int(spec["edges"] * scale), 64)
    if spec["kind"] == "road":
        side = int(np.sqrt(n))
        return road_network(side, seed=seed)
    return rmat(n, e, seed=seed)
