"""First-class algorithm registry + back-compat free functions.

An :class:`AlgorithmSpec` is the single record of an algorithm's
identity: which semiring runs the MAC datapath, which graph variant /
normalization the plan is built over, which update rule the engines
apply (and therefore — via the rule's ``monotone``/``bias`` properties —
which schedules the algorithm is eligible for), how the frontier vector
is initialized, how raw converged values are post-processed, and which
numpy oracle certifies it.  Every consumer dispatches through the
registry — ``GraphProcessor.run``, the distributed engines, the serving
layer's wave coalescing — so adding an algorithm is one
:func:`register_algorithm` call, not a five-layer edit.

    from repro.core.algorithms import AlgorithmSpec, register_algorithm
    register_algorithm(AlgorithmSpec(
        name="widest_path", semiring="max_min", source_required=True,
        init=lambda p, src, pol: ...))
    proc.run(QuerySpec(algo="widest_path", sources=(0,)))

The free functions below (``pagerank(g)``, ``sssp(g, 0)``, ...) are the
historical one-shot API: thin wrappers that build a single-query
``GraphProcessor`` session.  Code issuing many queries against one graph
should construct the processor directly so the compile-time pipeline
(cluster → permute → BSR build → upload) is paid once:

    from repro import api
    proc = api.GraphProcessor(g, b=16, num_clusters=64)
    proc.pagerank(); proc.sssp(0); proc.sssp(sources=[1, 2, 3])
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import numpy as np

from . import oracles, semiring as sr
from .graph import Graph


@dataclasses.dataclass(frozen=True)
class AlgorithmSpec:
    """One algorithm's complete identity on the NALE datapath.

    Attributes:
      name:        registry key; ``QuerySpec.algo`` strings resolve here.
      semiring:    ⊕/⊗ pair the sweeps run on (``semiring.get`` name).
      update:      apply rule name (``semiring.rule``); its
                   ``monotone``/``bias``/``exact`` properties drive
                   schedule eligibility in every engine flavor.
      variant:     graph transform the plan is built over — "base",
                   "unit" (unit weights), "undirected", or
                   "unit_undirected" (both).
      pull / normalize:  remaining plan-key fields (see ``PlanKey``).
      source_required:   query must carry at least one source vertex.
      coalescible: the serving layer may merge same-plan single-source
                   queries of this algorithm into one batched wave.
      default_policy:    per-algorithm ``ExecutionPolicy`` field
                   defaults, applied over the session policy when the
                   query does not pin an explicit policy.
      param_map:   QuerySpec.params name → ExecutionPolicy field; lets
                   an algorithm parameter (k-core's ``k``) ride an
                   existing scalar slot (``damping``) through engines
                   and kernels without widening every signature.
      required_params:   params that must be present (checked by
                   ``validate_spec`` before any plan work).
      init:        ``(prepared, src, policy) -> (n,) float32`` initial
                   state in ORIGINAL vertex ids.
      post:        converged values → user values (None = identity).
      pad:         padding value for absent/padded rows; None uses the
                   semiring's ⊕-identity (correct whenever init respects
                   the carrier set).
      oracle:      numpy reference implementation (signature varies per
                   algorithm; see ``core/oracles.py``).
      runner:      name of a ``GraphProcessor`` method implementing a
                   non-relaxation algorithm (one-shot/sequential
                   workloads: minitri, tricount, dfs).  When set, the
                   relaxation fields above are unused.
    """

    name: str
    semiring: str = "plus_times"
    update: str = "relax"
    variant: str = "base"
    pull: bool = True
    normalize: Optional[str] = None
    source_required: bool = False
    coalescible: bool = False
    default_policy: Tuple[Tuple[str, Any], ...] = ()
    param_map: Tuple[Tuple[str, str], ...] = ()
    required_params: Tuple[str, ...] = ()
    init: Optional[Callable] = None
    post: Optional[Callable] = None
    pad: Optional[float] = None
    oracle: Optional[Callable] = None
    runner: Optional[str] = None

    @property
    def rule(self) -> sr.UpdateRule:
        """Scheduling properties of this algorithm's update rule."""
        return sr.rule(self.update)

    @property
    def ring(self) -> sr.Semiring:
        return sr.get(self.semiring)


ALGORITHMS: dict = {}


def register_algorithm(spec: AlgorithmSpec,
                       overwrite: bool = False) -> AlgorithmSpec:
    """Register an algorithm for ``QuerySpec``/engine/serving dispatch."""
    sr.rule(spec.update)        # fail fast on unknown rule names
    if spec.runner is None:
        sr.get(spec.semiring)   # ... and unknown semirings
    if spec.name in ALGORITHMS and not overwrite:
        raise ValueError(
            f"algorithm {spec.name!r} is already registered; pass "
            "overwrite=True to replace it")
    ALGORITHMS[spec.name] = spec
    return spec


def get_algorithm(name: str) -> AlgorithmSpec:
    try:
        return ALGORITHMS[name]
    except KeyError:
        raise ValueError(
            f"unknown algorithm {name!r}; registered: {registered_algorithms()}")


def registered_algorithms() -> Tuple[str, ...]:
    return tuple(sorted(ALGORITHMS))


# ---------------------------------------------------------------------------
# built-in registrations — the paper's suite + the PR-9 families
# ---------------------------------------------------------------------------


def _init_source_inf(p, src, pol):
    x = np.full(p.n, np.inf, dtype=np.float32)
    x[src] = 0.0
    return x


def _init_source_one(p, src, pol):
    x = np.zeros(p.n, dtype=np.float32)
    x[src] = 1.0
    return x


def _init_uniform(p, src, pol):
    return np.full(p.n, 1.0 / p.n, dtype=np.float32)


def _init_delta_floor(p, src, pol):
    # the fixpoint is approached monotonically from below; (1-d)/n is
    # every vertex's rank floor (its bias term), so in-degree-0 vertices
    # start already converged — no first-touch bias sweep needed.
    return np.full(p.n, (1.0 - pol.damping) / p.n, dtype=np.float32)


def _init_perm_labels(p, src, pol):
    return p.perm.astype(np.float32)


def _init_ones(p, src, pol):
    return np.ones(p.n, dtype=np.float32)


def _renorm(v):
    return v / max(v.sum(), 1e-30)  # dangling-drop: L1 renormalization


register_algorithm(AlgorithmSpec(
    name="sssp", semiring="min_plus", source_required=True,
    coalescible=True, default_policy=(("max_sweeps", 100_000),),
    init=_init_source_inf, oracle=oracles.sssp_oracle))

register_algorithm(AlgorithmSpec(
    name="bfs", semiring="min_plus", variant="unit", source_required=True,
    coalescible=True, default_policy=(("max_sweeps", 100_000),),
    init=_init_source_inf, oracle=oracles.bfs_oracle))

register_algorithm(AlgorithmSpec(
    name="pagerank", semiring="plus_times", update="pagerank",
    normalize="out_stochastic",
    default_policy=(("tol", 1e-8), ("max_sweeps", 500)),
    init=_init_uniform, post=_renorm, oracle=oracles.pagerank_oracle))

# GraphScale's delta-accumulating PageRank: same plan (plus_times /
# out_stochastic — plan-cache shared with classic pagerank), but the
# update only *raises* ranks from the (1-d)/n floor, by more than tol at
# a time.  That makes it idempotent and monotone, hence eligible for the
# async engine and the self-timed distributed flavor that refuse the
# classic sweep; the price is a tolerance-bounded (not exact) fixpoint:
# ||x - x*||_inf <= tol / (1 - damping) before the final renorm.
register_algorithm(AlgorithmSpec(
    name="pagerank_delta", semiring="plus_times", update="pagerank_delta",
    normalize="out_stochastic",
    default_policy=(("tol", 1e-8), ("max_sweeps", 500)),
    init=_init_delta_floor, post=_renorm, oracle=oracles.pagerank_oracle))

register_algorithm(AlgorithmSpec(
    name="cc", semiring="min_select", variant="undirected",
    default_policy=(("max_sweeps", 100_000),),
    init=_init_perm_labels, oracle=oracles.cc_oracle))

register_algorithm(AlgorithmSpec(
    name="reachability", semiring="max_min", variant="unit",
    source_required=True,
    default_policy=(("max_sweeps", 100_000), ("mode", "sync")),
    init=_init_source_one, oracle=None))

# k-core membership peeling: plus_times over the unit-weight undirected
# graph makes each sweep's y a live-neighbour count; the "kcore" rule
# kills vertices with y < k.  k rides the damping scalar slot (the one
# per-rule float threshold the engines/kernels already plumb).
register_algorithm(AlgorithmSpec(
    name="kcore", semiring="plus_times", update="kcore",
    variant="unit_undirected",
    default_policy=(("max_sweeps", 100_000),),
    param_map=(("k", "damping"),), required_params=("k",),
    init=_init_ones, oracle=oracles.kcore_oracle))

register_algorithm(AlgorithmSpec(
    name="minitri", runner="_minitri_runner",
    oracle=oracles.triangles_oracle))

# per-vertex triangle counting on the minitri oriented-edge machinery
register_algorithm(AlgorithmSpec(
    name="tricount", runner="_tricount_runner",
    oracle=oracles.tricount_oracle))

register_algorithm(AlgorithmSpec(
    name="dfs", runner="_dfs_runner", source_required=True,
    oracle=oracles.dfs_oracle))


# ---------------------------------------------------------------------------
# back-compat free functions (lazy session construction)
# ---------------------------------------------------------------------------


def __getattr__(name):
    # AlgoResult/Result re-export without importing api at module load
    # (core/__init__ imports algorithms before api).
    if name in ("AlgoResult", "Result", "ExecutionPolicy"):
        from . import api as _api
        return {"AlgoResult": _api.Result, "Result": _api.Result,
                "ExecutionPolicy": _api.ExecutionPolicy}[name]
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def _proc(g: Graph, b: int = 32, num_clusters=None, clustered: bool = True):
    from . import api as _api
    return _api.GraphProcessor(g, b=b, num_clusters=num_clusters,
                               clustered=clustered)


def _policy(mode, impl, **kw):
    from . import api as _api
    return _api.ExecutionPolicy(mode=mode, impl=impl, **kw)


def pagerank(g: Graph, damping: float = 0.85, tol: float = 1e-8,
             mode: str = "async", b: int = 32,
             num_clusters: Optional[int] = None, clustered: bool = True,
             max_sweeps: int = 500, impl: str = "ref"):
    pol = _policy(mode, impl, damping=damping, tol=tol,
                  max_sweeps=max_sweeps)
    return _proc(g, b, num_clusters, clustered).pagerank(policy=pol)


def pagerank_delta(g: Graph, damping: float = 0.85, tol: float = 1e-8,
                   mode: str = "async", b: int = 32,
                   num_clusters: Optional[int] = None,
                   clustered: bool = True, max_sweeps: int = 500,
                   impl: str = "ref"):
    """Delta-accumulating PageRank — async/dist_async-eligible."""
    pol = _policy(mode, impl, damping=damping, tol=tol,
                  max_sweeps=max_sweeps)
    return _proc(g, b, num_clusters, clustered).pagerank_delta(policy=pol)


def sssp(g: Graph, src: int, mode: str = "async", b: int = 32,
         num_clusters: Optional[int] = None, clustered: bool = True,
         max_sweeps: int = 100_000, impl: str = "ref"):
    pol = _policy(mode, impl, max_sweeps=max_sweeps)
    return _proc(g, b, num_clusters, clustered).sssp(src, policy=pol)


def bfs(g: Graph, src: int, mode: str = "async", b: int = 32,
        num_clusters: Optional[int] = None, clustered: bool = True,
        max_sweeps: int = 100_000, impl: str = "ref"):
    pol = _policy(mode, impl, max_sweeps=max_sweeps)
    return _proc(g, b, num_clusters, clustered).bfs(src, policy=pol)


def connected_components(g: Graph, mode: str = "async", b: int = 32,
                         num_clusters: Optional[int] = None,
                         clustered: bool = True,
                         max_sweeps: int = 100_000, impl: str = "ref"):
    pol = _policy(mode, impl, max_sweeps=max_sweeps)
    return _proc(g, b, num_clusters,
                 clustered).connected_components(policy=pol)


def kcore(g: Graph, k: int, mode: str = "async", b: int = 32,
          num_clusters: Optional[int] = None, clustered: bool = True,
          max_sweeps: int = 100_000, impl: str = "ref"):
    """k-core membership: 1.0 for vertices in the k-core, else 0.0."""
    pol = _policy(mode, impl, max_sweeps=max_sweeps)
    return _proc(g, b, num_clusters, clustered).kcore(k, policy=pol)


def reachability(g: Graph, src: int, mode: str = "sync", b: int = 32,
                 num_clusters: Optional[int] = None,
                 clustered: bool = True, max_sweeps: int = 100_000,
                 impl: str = "ref"):
    """Boolean or_and reachability from src (max_min on {0,1})."""
    pol = _policy(mode, impl, max_sweeps=max_sweeps)
    return _proc(g, b, num_clusters, clustered).reachability(src,
                                                             policy=pol)


def minitri(g: Graph, chunk: int = 65536):
    return _proc(g).minitri(chunk=chunk)


def tricount(g: Graph, chunk: int = 65536):
    """Per-vertex triangle counts (values[v] = triangles at corner v)."""
    return _proc(g).tricount(chunk=chunk)


def dfs(g: Graph, src: int):
    return _proc(g).dfs(src)
