"""The paper's six benchmark algorithms on the graph engines.

SSSP, BFS, PageRank and CC run on the clustered BSR engines (sync or
async); MiniTri and DFS have their own data-parallel / sequential
formulations (triangle counting is a one-shot intersection workload; DFS
is inherently sequential and is included — as in the paper — to show the
architecture's behaviour on a worst-case-serial algorithm).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import engine as eng
from .graph import Graph, to_ell_fast


@dataclasses.dataclass
class AlgoResult:
    values: np.ndarray          # per-vertex output, ORIGINAL vertex ids
    stats: eng.RunStats
    prepared: Optional[eng.Prepared]
    extra: dict


def _run(p: eng.Prepared, x0, apply_kind, mode, **kw):
    if mode == "async":
        return eng.run_async(p, x0, apply_kind=apply_kind, **kw)
    return eng.run_sync(p, x0, apply_kind=apply_kind, **kw)


# ---------------------------------------------------------------------------


def pagerank(g: Graph, damping: float = 0.85, tol: float = 1e-8,
             mode: str = "async", b: int = 32,
             num_clusters: Optional[int] = None, clustered: bool = True,
             max_sweeps: int = 500) -> AlgoResult:
    p = eng.prepare(g, "plus_times", b=b, num_clusters=num_clusters,
                    pull=True, clustered=clustered,
                    normalize="out_stochastic")
    x0 = p.to_blocks(np.full(g.n, 1.0 / g.n, dtype=np.float32), 0.0)
    x, stats = _run(p, x0, "pagerank", mode, damping=damping, tol=tol,
                    max_sweeps=max_sweeps)
    v = p.from_blocks(x)
    v = v / max(v.sum(), 1e-30)  # dangling-drop semantics: L1 renormalize
    return AlgoResult(v, stats, p, {})


def sssp(g: Graph, src: int, mode: str = "async", b: int = 32,
         num_clusters: Optional[int] = None, clustered: bool = True,
         max_sweeps: int = 100_000) -> AlgoResult:
    p = eng.prepare(g, "min_plus", b=b, num_clusters=num_clusters,
                    pull=True, clustered=clustered)
    x0f = np.full(g.n, np.inf, dtype=np.float32)
    x0f[src] = 0.0
    x0 = p.to_blocks(x0f, np.inf)
    changed0 = None
    if mode == "async":
        ch = np.zeros(p.r_pad, dtype=bool)
        ch[int(p.perm[src]) // p.b] = True
        changed0 = jnp.asarray(ch)
    x, stats = _run(p, x0, "relax", mode, max_sweeps=max_sweeps,
                    **({"changed0": changed0} if mode == "async" else {}))
    return AlgoResult(p.from_blocks(x), stats, p, {"src": src})


def bfs(g: Graph, src: int, mode: str = "async", b: int = 32,
        num_clusters: Optional[int] = None, clustered: bool = True,
        max_sweeps: int = 100_000) -> AlgoResult:
    g1 = Graph(n=g.n, indptr=g.indptr, indices=g.indices,
               weights=np.ones(g.nnz, dtype=np.float32))
    res = sssp(g1, src, mode=mode, b=b, num_clusters=num_clusters,
               clustered=clustered, max_sweeps=max_sweeps)
    res.extra["levels"] = res.values
    return res


def connected_components(g: Graph, mode: str = "async", b: int = 32,
                         num_clusters: Optional[int] = None,
                         clustered: bool = True,
                         max_sweeps: int = 100_000) -> AlgoResult:
    und = g.to_undirected()
    p = eng.prepare(und, "min_select", b=b, num_clusters=num_clusters,
                    pull=True, clustered=clustered)
    # label = own (new) id; fixpoint = min reachable new id
    x0f = p.perm.astype(np.float32)
    x0 = p.to_blocks(x0f, np.inf)
    x, stats = _run(p, x0, "relax", mode, max_sweeps=max_sweeps)
    return AlgoResult(p.from_blocks(x), stats, p, {})


def reachability(g: Graph, src: int, mode: str = "sync", b: int = 32,
                 num_clusters: Optional[int] = None,
                 clustered: bool = True,
                 max_sweeps: int = 100_000) -> AlgoResult:
    """Boolean or_and reachability from src (max_min on {0,1})."""
    g1 = Graph(n=g.n, indptr=g.indptr, indices=g.indices,
               weights=np.ones(g.nnz, dtype=np.float32))
    p = eng.prepare(g1, "max_min", b=b, num_clusters=num_clusters,
                    pull=True, clustered=clustered)
    x0f = np.zeros(g.n, dtype=np.float32)
    x0f[src] = 1.0
    x0 = p.to_blocks(x0f, 0.0)
    x, stats = _run(p, x0, "relax", mode, max_sweeps=max_sweeps)
    return AlgoResult(p.from_blocks(x), stats, p, {"src": src})


# ---------------------------------------------------------------------------
# MiniTri — triangle counting:  Δ = Σ_{(u,v)∈E⁺} |N⁺(u) ∩ N⁺(v)|
# ---------------------------------------------------------------------------


@jax.jit
def _tri_count(rows: jnp.ndarray, eu: jnp.ndarray, ev: jnp.ndarray,
               sentinel: jnp.int32) -> jnp.ndarray:
    """rows: (n+1, k) sorted neighbour ids padded with `sentinel`; (eu, ev)
    oriented edges.  Batched sorted-intersection via searchsorted."""

    def one(u, v):
        a, bb = rows[u], rows[v]
        pos = jnp.searchsorted(bb, a)
        pos = jnp.clip(pos, 0, bb.shape[0] - 1)
        hit = (bb[pos] == a) & (a != sentinel)
        return jnp.sum(hit)

    return jnp.sum(jax.vmap(one)(eu, ev))


def minitri(g: Graph, chunk: int = 65536) -> AlgoResult:
    und = g.to_undirected()
    deg = und.out_degrees()
    src = np.repeat(np.arange(und.n, dtype=np.int64), np.diff(und.indptr))
    dst = und.indices.astype(np.int64)
    # orient low→high (degree, id): DAG with small max out-degree
    key_s = deg[src] * (und.n + 1) + src
    key_d = deg[dst] * (und.n + 1) + dst
    keep = key_s < key_d
    s2, d2 = src[keep], dst[keep]
    g_plus = Graph.from_edges(und.n, s2.astype(np.int32),
                              d2.astype(np.int32),
                              np.ones(len(s2), dtype=np.float32))
    ell = to_ell_fast(g_plus)
    rows = np.vstack([ell.cols, np.full((1, ell.k_max), und.n,
                                        dtype=np.int32)])  # +sentinel row
    eu = np.repeat(np.arange(und.n, dtype=np.int32),
                   np.diff(g_plus.indptr))
    ev = g_plus.indices.astype(np.int32)
    rows_j = jnp.asarray(rows)
    total = 0
    for i in range(0, len(eu), chunk):
        total += int(_tri_count(rows_j, jnp.asarray(eu[i:i + chunk]),
                                jnp.asarray(ev[i:i + chunk]),
                                jnp.int32(und.n)))
    e_plus = len(eu)
    # one-shot data-parallel workload: intersections distribute evenly
    # over the NALE array (no dependency chain), so the critical path is
    # total work / array width, not the serial stream
    nales = 256.0
    stats = eng.RunStats(
        sweeps=1, converged=True,
        tile_work=float(e_plus * ell.k_max),
        edge_work=float(e_plus * max(ell.k_max, 1)),
        crit_tiles=float(e_plus * ell.k_max) / nales,
        active_group_sweeps=nales, halo_tiles=0.0, total_groups=1,
        mode="oneshot")
    return AlgoResult(np.array([total]), stats, None,
                      {"triangles": total, "oriented_edges": e_plus,
                       "k_max": ell.k_max})


# ---------------------------------------------------------------------------
# DFS — sequential stack machine (worst case for any parallel substrate)
# ---------------------------------------------------------------------------


def dfs(g: Graph, src: int) -> AlgoResult:
    ell = to_ell_fast(g)
    n, k = g.n, ell.k_max
    cols = jnp.asarray(ell.cols)  # pad = n

    cap = g.nnz + n + 2

    @jax.jit
    def run():
        stack = jnp.zeros(cap, dtype=jnp.int32).at[0].set(src)
        pstack = jnp.full(cap, -1, dtype=jnp.int32)
        visited = jnp.zeros(n + 1, dtype=bool).at[n].set(True)
        order = jnp.full(n, -1, dtype=jnp.int32)
        parent = jnp.full(n, -1, dtype=jnp.int32)

        def cond(st):
            sp, *_ = st
            return sp > 0

        def body(st):
            sp, stack, pstack, visited, order, parent, cnt = st
            u = stack[sp - 1]
            pu = pstack[sp - 1]
            sp = sp - 1
            fresh = ~visited[u]

            def visit(args):
                sp, stack, pstack, visited, order, parent, cnt = args
                visited = visited.at[u].set(True)
                order = order.at[cnt].set(u)
                parent = parent.at[u].set(pu)
                # push neighbours in reverse so lowest pops first
                def push(i, a):
                    sp, stack, pstack = a
                    v = cols[u, k - 1 - i]
                    ok = ~visited[v]
                    stack = stack.at[sp].set(jnp.where(ok, v, stack[sp]))
                    pstack = pstack.at[sp].set(jnp.where(ok, u, pstack[sp]))
                    return sp + ok.astype(jnp.int32), stack, pstack
                sp, stack, pstack = jax.lax.fori_loop(
                    0, k, push, (sp, stack, pstack))
                return sp, stack, pstack, visited, order, parent, cnt + 1

            return jax.lax.cond(
                fresh, visit, lambda a: a,
                (sp, stack, pstack, visited, order, parent, cnt))

        st = (jnp.int32(1), stack, pstack, visited, order, parent,
              jnp.int32(0))
        sp, stack, pstack, visited, order, parent, cnt = \
            jax.lax.while_loop(cond, body, st)
        return order, parent, cnt

    order, parent, cnt = run()
    stats = eng.RunStats(
        sweeps=int(cnt), converged=True,
        tile_work=float(int(cnt) * k), edge_work=float(g.nnz),
        crit_tiles=float(int(cnt) * k), active_group_sweeps=float(int(cnt)),
        halo_tiles=0.0, total_groups=1, mode="sequential")
    return AlgoResult(np.asarray(order), stats, None,
                      {"parent": np.asarray(parent),
                       "visited_count": int(cnt)})
