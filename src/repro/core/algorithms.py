"""The paper's six benchmark algorithms — back-compat free functions.

These are thin wrappers over the session API (``core/api.py``): each call
builds a single-query ``GraphProcessor`` session.  Code that issues many
queries against one graph should construct the processor directly so the
compile-time pipeline (cluster → permute → BSR build → upload) is paid
once and shared across queries:

    from repro import api
    proc = api.GraphProcessor(g, b=16, num_clusters=64)
    proc.pagerank(); proc.sssp(0); proc.sssp(sources=[1, 2, 3])
"""

from __future__ import annotations

from typing import Optional

from . import api as _api
from .api import ExecutionPolicy, Result
from .graph import Graph

# the old result type is the new uniform one (same leading fields)
AlgoResult = Result


def _proc(g: Graph, b: int, num_clusters, clustered) -> _api.GraphProcessor:
    return _api.GraphProcessor(g, b=b, num_clusters=num_clusters,
                               clustered=clustered)


def pagerank(g: Graph, damping: float = 0.85, tol: float = 1e-8,
             mode: str = "async", b: int = 32,
             num_clusters: Optional[int] = None, clustered: bool = True,
             max_sweeps: int = 500, impl: str = "ref") -> AlgoResult:
    pol = ExecutionPolicy(mode=mode, impl=impl, damping=damping, tol=tol,
                          max_sweeps=max_sweeps)
    return _proc(g, b, num_clusters, clustered).pagerank(policy=pol)


def sssp(g: Graph, src: int, mode: str = "async", b: int = 32,
         num_clusters: Optional[int] = None, clustered: bool = True,
         max_sweeps: int = 100_000, impl: str = "ref") -> AlgoResult:
    pol = ExecutionPolicy(mode=mode, impl=impl, max_sweeps=max_sweeps)
    return _proc(g, b, num_clusters, clustered).sssp(src, policy=pol)


def bfs(g: Graph, src: int, mode: str = "async", b: int = 32,
        num_clusters: Optional[int] = None, clustered: bool = True,
        max_sweeps: int = 100_000, impl: str = "ref") -> AlgoResult:
    pol = ExecutionPolicy(mode=mode, impl=impl, max_sweeps=max_sweeps)
    return _proc(g, b, num_clusters, clustered).bfs(src, policy=pol)


def connected_components(g: Graph, mode: str = "async", b: int = 32,
                         num_clusters: Optional[int] = None,
                         clustered: bool = True,
                         max_sweeps: int = 100_000,
                         impl: str = "ref") -> AlgoResult:
    pol = ExecutionPolicy(mode=mode, impl=impl, max_sweeps=max_sweeps)
    return _proc(g, b, num_clusters,
                 clustered).connected_components(policy=pol)


def reachability(g: Graph, src: int, mode: str = "sync", b: int = 32,
                 num_clusters: Optional[int] = None,
                 clustered: bool = True, max_sweeps: int = 100_000,
                 impl: str = "ref") -> AlgoResult:
    """Boolean or_and reachability from src (max_min on {0,1})."""
    pol = ExecutionPolicy(mode=mode, impl=impl, max_sweeps=max_sweeps)
    return _proc(g, b, num_clusters, clustered).reachability(src,
                                                             policy=pol)


def minitri(g: Graph, chunk: int = 65536) -> AlgoResult:
    return _api.GraphProcessor(g).minitri(chunk=chunk)


def dfs(g: Graph, src: int) -> AlgoResult:
    return _api.GraphProcessor(g).dfs(src)
