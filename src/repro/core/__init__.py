# The paper's primary contribution: an asynchronous graph-processing
# architecture, adapted TPU-natively (see DESIGN.md §2).
#   graph/cluster  — Fig.4 compile-time steps 1–4 (topology → clusters →
#                    dependencies → placement)
#   semiring       — the NALE MAC/comparator datapath algebra
#   engine         — sync (BSP) vs async (cluster-dataflow, Gauss-Seidel)
#   algorithms     — SSSP, BFS, DFS, PageRank, MiniTri, CC
#   isa/compile    — the specialized ISA + step-5 codegen
#   power          — cycle & energy models for NALE / CPU / GPU classes
#   placement      — multi-device halo-exchange engine (shard_map)

from . import algorithms, api, cluster, compile, engine, graph, isa, \
    oracles, placement, power, semiring  # noqa: F401
