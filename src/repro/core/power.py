"""Analytical cycle and energy models for the three platform classes
(paper §III: graph processor vs. Heracles CPU vs. MIAOW GPGPU).

This container has no FPGA/TPU, so — like any architecture study without
silicon — performance and power are *modeled*.  Constants below are
standard-cell / literature ballpark numbers (45 nm-class, matching the
paper's FPGA-prototype era) and are reported alongside every result; the
*relative* claims (NALE vs CPU speedup, NALE vs GPU efficiency) are what
the reproduction validates, and those depend on the work/locality counters
measured by the engines, not on the absolute constants.

Model summary
  NALE array  : cycles = crit_tiles·(B+h) + sweeps·(fill+apply)
                — crit_tiles is the measured per-sweep critical path
                (max active cluster), i.e. perfectly self-timed elements
                limited only by the slowest cluster, no global barrier.
  CPU         : sequential worklist algorithm; cycles/edge =
                instr/edge·CPI + 2 loads·miss_rate·miss_penalty, with a
                cache-capacity miss model (graph >> cache ⇒ misses).
  GPU (SIMD)  : bulk-synchronous Jacobi over padded ELL rows (divergence
                = padding ratio); wide but must sweep everything.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .engine import Prepared, RunStats


@dataclasses.dataclass(frozen=True)
class NaleConfig:
    num_nales: int = 256          # processing elements (paper: scalable)
    freq_hz: float = 500e6        # FPGA-class clock-equivalent rate
    handshake: int = 2            # GasP handshake per tile
    fill: int = 8                 # pipeline fill per sweep
    e_mac_pj: float = 2.0         # per 32-bit MAC
    e_sram_pj_b: float = 0.5      # per byte, FIFO/VMEM
    e_dram_pj_b: float = 15.0     # per byte, main memory
    p_static_w: float = 0.15      # async logic: tiny idle power


@dataclasses.dataclass(frozen=True)
class CpuConfig:
    freq_hz: float = 1e9
    instr_per_edge: float = 8.0
    cpi: float = 1.2
    cache_bytes: float = 256e3    # Heracles-class soft core
    miss_penalty: int = 100
    loads_per_edge: float = 2.0
    e_instr_pj: float = 70.0      # full in-order pipeline per instr
    e_dram_pj_b: float = 15.0
    p_static_w: float = 0.5


@dataclasses.dataclass(frozen=True)
class GpuConfig:
    freq_hz: float = 800e6
    lanes: int = 1024             # SIMD width × CUs (MIAOW-class)
    cycles_per_edge: float = 1.0
    sweep_overhead: int = 2000    # kernel launch / global barrier
    e_op_pj: float = 15.0
    e_dram_pj_b: float = 15.0
    p_static_w: float = 25.0      # clocked SIMD array + scheduler idle


@dataclasses.dataclass
class PlatformReport:
    platform: str
    cycles: float
    time_s: float
    energy_j: float
    power_w: float

    @property
    def perf_per_watt(self) -> float:
        return 1.0 / (self.time_s * self.power_w) if self.time_s else 0.0


def _miss_rate(n_vertices: int, cfg: CpuConfig) -> float:
    working = n_vertices * 8.0
    return float(np.clip(1.0 - cfg.cache_bytes / max(working, 1.0),
                         0.02, 0.98))


def model_nale(p: Prepared, stats: RunStats,
               cfg: NaleConfig = NaleConfig()) -> PlatformReport:
    b = p.b
    # parallelism: clusters map onto NALEs; if clusters > NALEs they
    # time-multiplex (cluster-mode internal FIFO), folding the critical path
    fold = max(1.0, p.s / cfg.num_nales)
    cycles = stats.crit_tiles * (b + cfg.handshake) * fold \
        + stats.sweeps * (cfg.fill + p.gb)
    time_s = cycles / cfg.freq_hz
    macs = stats.tile_work * b * b
    bytes_tiles = stats.tile_work * b * b * 4.0        # streamed from DRAM
    bytes_halo = stats.halo_tiles * b * 4.0            # FIFO/on-chip
    energy = (macs * cfg.e_mac_pj + bytes_tiles * cfg.e_dram_pj_b
              + bytes_halo * cfg.e_sram_pj_b) * 1e-12 \
        + cfg.p_static_w * time_s
    return PlatformReport("nale", float(cycles), float(time_s),
                          float(energy),
                          float(energy / time_s) if time_s else 0.0)


def model_cpu(p: Prepared, stats: RunStats,
              cfg: CpuConfig = CpuConfig()) -> PlatformReport:
    """Sequential CPU running the classic worklist algorithm: its total
    edge relaxations ≈ the async engine's edge_work (same data-driven
    semantics, but serialized on one core with a cache)."""
    mr = _miss_rate(p.n, cfg)
    per_edge = cfg.instr_per_edge * cfg.cpi \
        + cfg.loads_per_edge * mr * cfg.miss_penalty
    cycles = stats.edge_work * per_edge
    time_s = cycles / cfg.freq_hz
    energy = (stats.edge_work * cfg.instr_per_edge * cfg.e_instr_pj
              + stats.edge_work * cfg.loads_per_edge * mr * 64
              * cfg.e_dram_pj_b) * 1e-12 + cfg.p_static_w * time_s
    return PlatformReport("cpu", float(cycles), float(time_s),
                          float(energy),
                          float(energy / time_s) if time_s else 0.0)


def model_gpu(p: Prepared, stats_sync: RunStats, k_max_pad: float,
              avg_degree: float,
              cfg: GpuConfig = GpuConfig()) -> PlatformReport:
    """GPU executes bulk-synchronous sweeps over ELL-padded rows; SIMD
    divergence charges padded (not true) edges.  Needs *sync* sweep count."""
    pad_ratio = max(k_max_pad / max(avg_degree, 1e-9), 1.0)
    padded_edges = stats_sync.edge_work * pad_ratio
    cycles = padded_edges * cfg.cycles_per_edge / cfg.lanes \
        + stats_sync.sweeps * cfg.sweep_overhead
    time_s = cycles / cfg.freq_hz
    energy = (padded_edges * cfg.e_op_pj
              + padded_edges * 12 * cfg.e_dram_pj_b) * 1e-12 \
        + cfg.p_static_w * time_s
    return PlatformReport("gpu", float(cycles), float(time_s),
                          float(energy),
                          float(energy / time_s) if time_s else 0.0)
