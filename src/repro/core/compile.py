"""Compilation pipeline (paper Fig. 4): profile → cluster → dependency
analysis → placement → codegen to the graph ISA.

``prepare`` (engine.py) already performs steps 1–4 (it holds the
Clustering and the BSR image); this module performs step 5 — emitting one
ISA ``Program`` per cluster — plus the static per-sweep cost table the
cycle model consumes.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np

from . import isa
from . import semiring as sr
from .engine import Prepared

# stable ISA rule ids (the GCFG operand); historical names keep their
# ids, anything else registered in semiring.UPDATE_RULES gets one
# appended in registration order
APPLY_RULES = {"relax": 0, "pagerank": 1, "identity": 2}
for _name in sr.UPDATE_RULES:
    APPLY_RULES.setdefault(_name, len(APPLY_RULES))
del _name


@dataclasses.dataclass
class CompiledGraphProgram:
    programs: List[isa.Program]
    cluster_order: np.ndarray          # schedule (engine group ids)
    static_cycles: np.ndarray          # (S,) cycles per full cluster sweep
    instr_total: Dict[str, int]
    b: int

    def total_instructions(self) -> int:
        return sum(len(p) for p in self.programs)


def compile_graph_program(p: Prepared, apply_kind: str = "relax"
                          ) -> CompiledGraphProgram:
    """Emit per-cluster NALE programs from the prepared (clustered) image."""
    cols = np.asarray(p.cols)
    nnz = np.asarray(p.nnz)
    sr.rule(apply_kind)  # unknown rules fail with the registry's error
    rule = APPLY_RULES.setdefault(apply_kind, len(APPLY_RULES))
    programs: List[isa.Program] = []
    static = np.zeros(p.s, dtype=np.int64)
    total: Dict[str, int] = {k: 0 for k in isa.OPCODES}

    grp_of_block = np.arange(p.r_pad) // p.gb
    for s in range(p.s):
        rows = range(s * p.gb, (s + 1) * p.gb)
        ins: List[np.ndarray] = [isa.instr("GCFG", 0, rule),
                                 isa.instr("GCFG", 1, p.b)]
        # receive halo blocks from upstream clusters (FIFO blocks until
        # data ready — this is the handshake that replaces the clock)
        ext_srcs = set()
        for r in rows:
            for k in range(int(nnz[r])):
                cb = int(cols[r, k])
                if grp_of_block[cb] != s:
                    ext_srcs.add(int(grp_of_block[cb]))
        for src in sorted(ext_srcs):
            ins.append(isa.instr("GRCV", src, 1))
        loaded = set()
        for r in rows:
            for k in range(int(nnz[r])):
                cb = int(cols[r, k])
                if cb not in loaded:
                    ins.append(isa.instr("GLDX", cb))
                    loaded.add(cb)
                ins.append(isa.instr("GMAC", k, cb))
            if nnz[r] or sr.rule(apply_kind).bias:
                ins.append(isa.instr("GCMP", r))
                ins.append(isa.instr("GAPP", r, rule))
        for dst in sorted(ext_srcs):  # symmetric notification downstream
            ins.append(isa.instr("GSND", dst, 1))
        ins.append(isa.instr("GSYN"))
        prog = isa.assemble(s, ins)
        programs.append(prog)
        static[s] = prog.static_cycles(p.b)
        for k, v in prog.histogram().items():
            total[k] += v

    return CompiledGraphProgram(
        programs=programs,
        cluster_order=np.arange(p.s, dtype=np.int32),
        static_cycles=static, instr_total=total, b=p.b)
