"""Session API — prepare once, query many (paper Fig. 4 split).

The paper separates *compile-time* work (profile → cluster → analyze →
place) from *run-time* execution on the self-timed NALE array.
``GraphProcessor`` is that split as an API: constructing one builds the
session; each query then runs against cached ``Prepared`` images — the
clustering/permutation and the device-resident BSR tiles are shared by
every algorithm that can use the same plan (keyed by semiring, graph
variant, direction, normalization and tiling), so serving many queries on
one graph pays the compile-time pipeline once.  PIUMA and GraphScale
expose the same load-once / query-many shape.

    proc = GraphProcessor(g, b=16, num_clusters=64)
    pr   = proc.pagerank()                       # prepares plus_times plan
    d    = proc.sssp(0)                          # prepares min_plus plan
    d2   = proc.sssp(5)                          # plan-cache hit: no rework
    dist = proc.sssp(sources=[0, 5, 9])          # batched: one vmap'd run

Execution is controlled by one ``ExecutionPolicy`` (engine mode, kernel
impl, convergence knobs) instead of per-function keyword scatter; every
query returns a uniform ``Result`` bundling per-vertex values, the
engine's measured ``RunStats``, and (via ``platform_models``) the
analytical NALE/CPU/GPU cycle & power models.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Dict, Mapping, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from . import engine as eng
from .algorithms import (AlgorithmSpec, get_algorithm,  # noqa: F401
                         register_algorithm, registered_algorithms)
from .engine import Prepared, RunStats
from .graph import Graph, to_ell_fast
from ..kernels.spec import KernelSpec, as_kernel_spec

MODES = ("sync", "async", "distributed")
IMPLS = ("ref", "pallas")
DIST_FLAVORS = ("sync", "async")


@dataclasses.dataclass(frozen=True)
class ExecutionPolicy:
    """How a query executes — one object for the knobs that used to be
    scattered across the ``algorithms.*`` free functions.

    mode:  "sync" (BSP/Jacobi baseline) | "async" (the paper's self-timed
           cluster-dataflow engine) | "distributed" (shard_map halo-
           exchange engine over the 2-D ("graph", "query") mesh).
    kernel:  a ``kernels.spec.KernelSpec`` — which kernel runs the
           sweeps and how (impl, block_size, rows_per_step,
           fuse_frontier, autotune).  None derives one from ``impl``.
           The distributed engine requires the "ref" kernel (Pallas
           calls cannot be SPMD-partitioned across host meshes).
    impl:  DEPRECATED alias for ``kernel=KernelSpec(impl=...)`` — "ref"
           (XLA-fused jnp) | "pallas" (Mosaic kernel; interpret mode
           off-TPU).  After construction ``impl`` always equals
           ``kernel.impl`` (both spellings compare/hash consistently).
    query_axis:  batched-distributed mesh factorization.  None (default)
           auto-factors the device count against the batch size
           (``placement.factor_query_axis``); an int >= 1 pins the
           "query" mesh extent (must divide the device count); 0 is the
           escape hatch back to the retired per-source sequential loop.
    dist_flavor:  exchange schedule of the distributed engine.  "sync"
           (default) is the bulk-synchronous path — one halo exchange
           per sweep; "async" is the self-timed engine
           (``core.async_dist``) — ``local_sweeps`` Gauss-Seidel
           relaxations per exchange with an overlapped, double-buffered
           halo, bit-identical at convergence for the idempotent
           "relax" algorithms (SSSP/BFS/CC/reachability).
    local_sweeps:  k, local sweeps per halo exchange; only meaningful
           (and only legal ≠ 1) with ``dist_flavor="async"``.
    degrade:  graceful-degradation ladder (True by default).  When an
           engine dispatch fails at run time, ``GraphProcessor.run``
           retries the query one rung down — a pallas/fused kernel
           failure re-runs on ``kernel=ref`` (bit-identical values), a
           distributed dispatch failure falls back to single-device
           ``mode="sync"`` — recording each step in
           ``Result.extra["degraded"]``.  API-misuse errors
           (ValueError/TypeError/KeyError/IndexError) never degrade: a
           request
           that can never execute must say so, not silently run
           something else.  ``degrade=False`` restores fail-fast.
    """

    mode: str = "async"
    impl: Optional[str] = None
    damping: float = 0.85
    tol: float = 1e-6
    max_sweeps: int = 10_000
    query_axis: Optional[int] = None
    dist_flavor: str = "sync"
    local_sweeps: int = 1
    kernel: Optional[KernelSpec] = None
    degrade: bool = True

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(f"mode must be one of {MODES}: {self.mode!r}")
        # normalize the (impl, kernel) pair: afterwards kernel is always a
        # KernelSpec and impl mirrors kernel.impl, so the deprecated and
        # structured spellings compare/hash equal.
        if self.kernel is not None and not isinstance(self.kernel,
                                                      KernelSpec):
            object.__setattr__(self, "kernel", as_kernel_spec(self.kernel))
        if self.kernel is None:
            impl = self.impl if self.impl is not None else "ref"
            if impl == "pallas":
                warnings.warn(
                    "ExecutionPolicy(impl='pallas') is deprecated; pass "
                    "kernel=KernelSpec(impl='pallas', ...) to reach the "
                    "tiling/fusion/autotune surface",
                    DeprecationWarning, stacklevel=3)
            object.__setattr__(self, "kernel", KernelSpec(impl=impl))
            object.__setattr__(self, "impl", impl)
        else:
            if self.impl is not None and self.impl != self.kernel.impl:
                raise ValueError(
                    f"impl={self.impl!r} conflicts with kernel.impl="
                    f"{self.kernel.impl!r}; set only kernel= (impl= is "
                    "the deprecated alias)")
            object.__setattr__(self, "impl", self.kernel.impl)
        if self.mode == "distributed" and self.kernel.impl != "ref":
            raise ValueError(
                "the distributed engine shard_maps the ref kernel; "
                "Pallas calls cannot be SPMD-partitioned — use "
                "mode='sync'/'async' for kernel.impl='pallas'")
        if self.query_axis is not None and self.query_axis < 0:
            raise ValueError(
                "query_axis must be None (auto), 0 (per-source "
                f"fallback) or a positive extent: {self.query_axis!r}")
        if self.dist_flavor not in DIST_FLAVORS:
            raise ValueError(
                f"dist_flavor must be one of {DIST_FLAVORS}: "
                f"{self.dist_flavor!r}")
        if self.local_sweeps < 1:
            raise ValueError(
                f"local_sweeps must be >= 1, got {self.local_sweeps!r}")
        if self.dist_flavor == "async" and self.mode != "distributed":
            raise ValueError(
                "dist_flavor='async' selects the self-timed distributed "
                f"engine and requires mode='distributed', not "
                f"{self.mode!r}")
        if self.local_sweeps != 1 and self.dist_flavor != "async":
            raise ValueError(
                f"local_sweeps={self.local_sweeps} needs "
                "dist_flavor='async'; the bulk-synchronous engine "
                "exchanges every sweep by construction")
        if self.dist_flavor == "async" and self.query_axis == 0:
            raise ValueError(
                "query_axis=0 (per-source sequential fallback) has no "
                "async flavor; use query_axis=None or a mesh extent")

    def but(self, **kw) -> "ExecutionPolicy":
        """Copy with overrides (policy objects are frozen).

        Overriding ``impl=`` or ``kernel=`` alone re-derives the other
        half of the normalized pair, so single-field overrides never
        trip the impl/kernel conflict check."""
        if "impl" in kw and "kernel" not in kw:
            kw["kernel"] = None
        elif "kernel" in kw and "impl" not in kw:
            kw["impl"] = None
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class PlanKey:
    """Everything that determines a ``Prepared`` image for one graph.

    Paired with the graph's content :meth:`~repro.core.graph.Graph.
    fingerprint`, a PlanKey is globally unique — that pair is the key of
    the cross-process plan store (``serve.graph.PlanStore``).
    """

    semiring: str
    variant: str          # base | unit | undirected — graph transform
    pull: bool
    normalize: Optional[str]
    b: int
    num_clusters: Optional[int]
    clustered: bool
    seed: int = 0         # clustering seed (part of plan identity)
    # Prepared images are kernel-agnostic and keyed with kernel=None (the
    # base key — existing stores stay valid); autotune records are keyed
    # by replace(base_key, kernel=requesting_spec), so tunings ride the
    # same (fingerprint, PlanKey) scheme without duplicating plans.
    kernel: Optional[KernelSpec] = None


@dataclasses.dataclass(frozen=True)
class QuerySpec:
    """One query against a session: algorithm + sources + policy.

    ``params`` are policy-field overrides applied over ``policy``; they
    may be given as a plain dict (``{"max_sweeps": 1}``) or as the
    historical tuple-of-tuples — dicts are normalized on construction so
    the spec stays hashable either way.
    """

    algo: str                                   # an AlgorithmSpec name
                                                # (core/algorithms.py)
    sources: Tuple[int, ...] = ()
    batched: bool = False                       # sources is a query axis
    policy: Optional[ExecutionPolicy] = None    # None → session default
    params: Union[Mapping[str, float],
                  Tuple[Tuple[str, float], ...]] = ()

    def __post_init__(self):
        # fail at construction, not deep in engine dispatch: the error
        # lists every registered algorithm
        get_algorithm(self.algo)
        items = self.params.items() if isinstance(self.params, Mapping) \
            else ((str(k), v) for k, v in self.params)
        # sorted in both forms: equivalent specs must compare/hash equal
        object.__setattr__(self, "params", tuple(sorted(items)))


@dataclasses.dataclass
class Result:
    """Uniform query result.

    ``values`` is per-vertex output in ORIGINAL vertex ids — shape (n,)
    for single queries, (Q, n) for batched multi-source queries.  The
    leading four fields match the old ``algorithms.AlgoResult`` layout,
    which is kept as an alias.
    """

    values: np.ndarray
    stats: RunStats
    prepared: Optional[Prepared]
    extra: dict
    policy: Optional[ExecutionPolicy] = None
    graph: Optional[Graph] = None

    def platform_models(self, sync_stats: Optional[RunStats] = None
                        ) -> dict:
        """Analytical NALE/CPU/GPU models (core/power.py) for this run.

        The GPU model needs bulk-synchronous sweep counts; it is included
        when this result is already sync or when ``sync_stats`` is given.
        """
        from . import power as PW
        if self.prepared is None:
            raise ValueError(
                f"{self.extra.get('algo', 'this')} result has no BSR "
                "image; platform models need a prepared plan")
        rep = {"nale": PW.model_nale(self.prepared, self.stats),
               "cpu": PW.model_cpu(self.prepared, self.stats)}
        ss = sync_stats or (self.stats if self.stats.mode == "sync"
                            else None)
        if ss is not None and self.graph is not None:
            k_pad = max(float(np.diff(self.graph.indptr).max()), 1.0)
            rep["gpu"] = PW.model_gpu(self.prepared, ss, k_max_pad=k_pad,
                                      avg_degree=self.graph.avg_degree)
        return rep


# back-compat aliases (snapshotted at import; the registry in
# core/algorithms.py is the source of truth and grows at runtime)
ALGOS = registered_algorithms()
SOURCE_REQUIRED = tuple(n for n in ALGOS
                        if get_algorithm(n).source_required)


def validate_spec(spec: QuerySpec) -> None:
    """Raise on specs that can never execute.  Shared by
    ``GraphProcessor.run`` and the serving layer's ``submit`` (which
    must reject bad requests before they can ride in a batch)."""
    a = get_algorithm(spec.algo)
    if a.source_required and not spec.sources:
        raise ValueError(
            f"{spec.algo} requires at least one source vertex")
    given = dict(spec.params)
    missing = [k for k in a.required_params if k not in given]
    if missing:
        raise ValueError(
            f"{spec.algo} requires params={{{', '.join(repr(m) for m in missing)}: ...}}"
            f" (e.g. QuerySpec(algo={spec.algo!r}, "
            f"params={{{missing[0]!r}: 2}}))")
    if len(spec.sources) > 1 and not spec.batched:
        raise ValueError(
            f"{len(spec.sources)} sources with batched=False would "
            "silently run only the first; set batched=True (or submit "
            "one spec per source)")


def _policy_desc(pol: ExecutionPolicy) -> str:
    """Short human tag for a degradation step record."""
    tag = f"{pol.mode}/{pol.kernel.impl}"
    if pol.kernel.fuse_frontier:
        tag += "+fused"
    if pol.mode == "distributed":
        tag += f"/{pol.dist_flavor}"
    return tag


def degrade_policy(pol: ExecutionPolicy) -> Optional[ExecutionPolicy]:
    """One rung down the graceful-degradation ladder, or None at the
    bottom.  Each rung trades the paper's performance machinery for a
    simpler engine that computes the *same values*:

      1. pallas / fused kernel  →  the ``ref`` kernel (same mode).  The
         kernel parity suite pins ref and pallas/fused bit-identical, so
         a degraded result is the healthy result.
      2. ``mode="distributed"``  →  single-device ``mode="sync"``.  The
         distributed engines are bit-identical to sync at convergence,
         so again only the cost changes.

    The ladder only changes *how* a query runs, never what it computes —
    which is what lets ``GraphProcessor.run`` retry down it behind the
    caller's back and still honor the bit-identical serving contract.
    """
    if pol.kernel is not None and pol.kernel.impl != "ref":
        return pol.but(kernel=KernelSpec(impl="ref"))
    if pol.mode == "distributed":
        return pol.but(mode="sync", dist_flavor="sync", local_sweeps=1,
                       query_axis=None)
    return None


class GraphProcessor:
    """Prepare-once / query-many session over one graph.

    Holds a plan cache of ``Prepared`` images keyed by ``PlanKey`` so
    repeated and cross-algorithm queries share the compile-time pipeline
    (clustering, permutation, BSR build, device upload), plus derived
    graph variants (unit-weight, undirected) built at most once.

    When ``store`` (a ``serve.graph.PlanStore``) is injected, plans are
    *borrowed* from it instead of owned: every ``prepare`` consults the
    shared store under ``(graph_fingerprint, PlanKey)``, so plans are
    shared across processors, across graphs registered in one
    ``GraphService``, and — through the store's on-disk cache — across
    process restarts.  Eviction then lives in exactly one place (the
    store); the processor keeps no private copy.
    """

    def __init__(self, g: Graph, b: int = 32,
                 num_clusters: Optional[int] = None, clustered: bool = True,
                 seed: int = 0, policy: Optional[ExecutionPolicy] = None,
                 store=None):
        self.g = g
        self.b = b
        self.num_clusters = num_clusters
        self.clustered = clustered
        self.seed = seed
        self.policy = policy or ExecutionPolicy()
        self.store = store
        self._plans: Dict[PlanKey, Prepared] = {}
        self._tunings: Dict[PlanKey, dict] = {}  # session-local fallback
        self._variants: Dict[str, Graph] = {"base": g}
        self._prepare_calls = 0
        self._autotune_calls = 0

    # -- compile-time pipeline (cached) ---------------------------------

    def _variant(self, name: str) -> Graph:
        if name not in self._variants:
            g = self.g
            if name == "unit":
                self._variants[name] = Graph(
                    n=g.n, indptr=g.indptr, indices=g.indices,
                    weights=np.ones(g.nnz, dtype=np.float32))
            elif name == "undirected":
                self._variants[name] = g.to_undirected()
            elif name == "unit_undirected":
                und = self._variant("undirected")
                self._variants[name] = Graph(
                    n=und.n, indptr=und.indptr, indices=und.indices,
                    weights=np.ones(und.nnz, dtype=np.float32))
            else:
                raise ValueError(f"unknown graph variant {name!r}")
        return self._variants[name]

    def plan_key(self, semiring: str, variant: str = "base",
                 pull: bool = True, normalize: Optional[str] = None
                 ) -> PlanKey:
        return PlanKey(semiring, variant, pull, normalize, self.b,
                       self.num_clusters, self.clustered, self.seed)

    def prepare(self, semiring: str, variant: str = "base",
                pull: bool = True, normalize: Optional[str] = None,
                kernel: Optional[KernelSpec] = None) -> Prepared:
        """Fetch (or build and cache) the Prepared image for a plan.

        With an injected store the lookup (and LRU/byte accounting) is
        delegated; without one, plans live in a session-local dict.
        Passing a ``kernel`` with ``autotune=True`` also runs (or
        fetches) the measured tuning sweep now, so the first query pays
        no calibration latency.
        """
        key = self.plan_key(semiring, variant, pull, normalize)
        if self.store is not None:
            p = self.store.get(self.g.fingerprint(), key)
            if p is None:
                self._prepare_calls += 1
                p = self._build(semiring, variant, pull, normalize)
                self.store.put(self.g.fingerprint(), key, p)
        else:
            p = self._plans.get(key)
            if p is None:
                self._prepare_calls += 1
                p = self._build(semiring, variant, pull, normalize)
                self._plans[key] = p
        if kernel is not None and kernel.autotune:
            self._ensure_tuning(p, key, kernel)
        return p

    def _build(self, semiring: str, variant: str, pull: bool,
               normalize: Optional[str]) -> Prepared:
        return eng.prepare(self._variant(variant), semiring, b=self.b,
                           num_clusters=self.num_clusters, pull=pull,
                           clustered=self.clustered, normalize=normalize,
                           seed=self.seed)

    def cache_info(self) -> dict:
        info = {"plans": len(self._plans),
                "prepare_calls": self._prepare_calls,
                "autotune_calls": self._autotune_calls,
                "tunings": len(self._tunings),
                "keys": list(self._plans)}
        if self.store is not None:
            info["store"] = self.store.stats()
        return info

    # -- measured kernel tunings (cached beside the plan) ----------------

    def _ensure_tuning(self, p: Prepared, key: PlanKey,
                       spec: KernelSpec) -> dict:
        """Fetch-or-measure the tuning record for (plan, spec).  Records
        ride the plan store's ``(fingerprint, PlanKey)`` scheme under
        ``replace(base_key, kernel=spec)`` so warm restarts reuse them;
        without a store they live for the session."""
        from ..kernels import autotune as at
        tkey = dataclasses.replace(key, kernel=spec)
        if self.store is not None and hasattr(self.store, "get_tuning"):
            fp = self.g.fingerprint()
            rec = self.store.get_tuning(fp, tkey)
            if rec is None:
                self._autotune_calls += 1
                rec = at.autotune_spmv(p, spec, seed=self.seed)
                self.store.put_tuning(fp, tkey, rec)
            return rec
        rec = self._tunings.get(tkey)
        if rec is None:
            self._autotune_calls += 1
            rec = at.autotune_spmv(p, spec, seed=self.seed)
            self._tunings[tkey] = rec
        return rec

    def _kernel_for_run(self, p: Prepared, key: PlanKey,
                        spec: KernelSpec) -> KernelSpec:
        """The concrete spec a query executes: autotuned knobs filled in
        from the cached (or freshly measured) tuning record."""
        if spec.impl != "pallas" or not spec.autotune:
            return spec
        return spec.concrete(self._ensure_tuning(p, key, spec))

    # -- unified run entry point ----------------------------------------

    def resolve_policy(self, spec: QuerySpec) -> ExecutionPolicy:
        """The effective policy for a spec: explicit policy (or session
        default merged with the algorithm's registered defaults), then
        ``params`` overrides (translated through the algorithm's
        ``param_map``, so e.g. k-core's ``k`` rides the damping scalar
        slot).  Exposed so the serving layer can group same-policy
        requests for coalescing exactly as ``run`` would execute them."""
        a = get_algorithm(spec.algo)
        pol = spec.policy or self.policy.but(**dict(a.default_policy))
        if spec.params:
            pm = dict(a.param_map)
            pol = pol.but(**{pm.get(k, k): v
                             for k, v in dict(spec.params).items()})
        return pol

    def run(self, spec: QuerySpec) -> Result:
        """Execute one QuerySpec.  All algorithm methods route here.

        Run-time engine failures walk the graceful-degradation ladder
        (see :func:`degrade_policy`) while ``policy.degrade`` is set:
        the query re-executes one rung down, each step recorded in
        ``Result.extra["degraded"]`` as ``{"from", "to", "error"}``.
        Errors that mean the request itself is wrong (ValueError /
        TypeError / KeyError — bad spec, ineligible flavor, missing
        kernel registration) always propagate: degradation absorbs
        *infrastructure* failures, not caller mistakes.
        """
        validate_spec(spec)
        a = get_algorithm(spec.algo)
        pol = self.resolve_policy(spec)
        if a.runner is not None:
            return getattr(self, a.runner)(spec, pol)
        steps: list = []
        while True:
            try:
                res = self._execute(spec, pol)
            except (ValueError, TypeError, KeyError, IndexError):
                raise
            except Exception as e:
                nxt = degrade_policy(pol) if pol.degrade else None
                if nxt is None:
                    raise
                steps.append({"from": _policy_desc(pol),
                              "to": _policy_desc(nxt),
                              "error": f"{type(e).__name__}: {e}"})
                pol = nxt
                continue
            if steps:
                res.extra["degraded"] = steps
            return res

    def _execute(self, spec: QuerySpec, pol: ExecutionPolicy) -> Result:
        """One engine attempt at (spec, pol) — the pre-ladder ``run``."""
        p, key, x0f, pad, apply_kind, post = self._relaxation_setup(
            spec, pol)
        kern = self._kernel_for_run(p, key, pol.kernel)
        if spec.batched:
            return self._run_batched(spec, pol, p, x0f, pad, apply_kind,
                                     post, kern)
        src = spec.sources[0] if spec.sources else None
        x0 = p.to_blocks(x0f(src), pad)
        x, stats, extra = self._dispatch(pol, p, x0, apply_kind, src,
                                         kern)
        values = post(p.from_blocks(x))
        extra = dict(extra, algo=spec.algo,
                     **({"src": src} if src is not None else {}))
        return Result(values, stats, p, extra, policy=pol, graph=self.g)

    # -- registry-driven plan + frontier-init descriptors ----------------

    def _relaxation_setup(self, spec: QuerySpec, pol: ExecutionPolicy):
        """Returns (Prepared, PlanKey, x0_builder(src), pad, apply_kind,
        post) — all read off the algorithm's registered
        ``AlgorithmSpec``; no per-algorithm branching here."""
        a = get_algorithm(spec.algo)
        key = self.plan_key(a.semiring, variant=a.variant, pull=a.pull,
                            normalize=a.normalize)
        p = self.prepare(a.semiring, variant=a.variant, pull=a.pull,
                         normalize=a.normalize)
        pad = float(a.ring.zero) if a.pad is None else a.pad
        post = a.post if a.post is not None else (lambda v: v)
        return p, key, (lambda src: a.init(p, src, pol)), pad, \
            a.update, post

    def _frontier(self, p: Prepared, src: Optional[int]) -> jnp.ndarray:
        """Initial changed-set for the async engine: just the source's
        row-block when there is a point source, else everything."""
        if src is None:
            return jnp.ones(p.r_pad, dtype=bool)
        ch = np.zeros(p.r_pad, dtype=bool)
        ch[int(p.perm[src]) // p.b] = True
        return jnp.asarray(ch)

    # -- engine dispatch -------------------------------------------------

    def _dispatch(self, pol: ExecutionPolicy, p: Prepared, x0,
                  apply_kind: str, src: Optional[int],
                  kern: Optional[KernelSpec] = None):
        kern = kern if kern is not None else pol.kernel
        kw = dict(apply_kind=apply_kind, damping=pol.damping, tol=pol.tol,
                  max_sweeps=pol.max_sweeps)
        if pol.mode == "sync":
            ch0 = self._frontier(p, src) if kern.fuse_frontier else None
            x, stats = eng.run_sync(p, x0, kernel=kern, changed0=ch0,
                                    **kw)
            return x, stats, {}
        if pol.mode == "async":
            x, stats = eng.run_async(p, x0, kernel=kern,
                                     changed0=self._frontier(p, src), **kw)
            return x, stats, {}
        # distributed: shard_map engine over the device mesh (ref
        # kernels).  dist_flavor picks the exchange schedule: "sync" =
        # bulk-synchronous (one exchange per sweep), "async" = self-timed
        # k-local-sweep engine with overlapped halo (core.async_dist).
        from . import placement
        if pol.dist_flavor == "async":
            from . import async_dist
            x, dist = async_dist.distributed_async_run(
                p, x0, local_sweeps=pol.local_sweeps, **kw)
            stats = eng.dist_run_stats(p, dist)
            return x, stats, {"dist": dist}
        x, dist = placement.distributed_sync_run(p, x0, **kw)
        stats = eng.bsp_stats(p, dist.sweeps, dist.converged,
                              "distributed")
        return x, stats, {"dist": dist}

    def _run_batched(self, spec: QuerySpec, pol: ExecutionPolicy,
                     p: Prepared, x0f, pad, apply_kind, post,
                     kern: Optional[KernelSpec] = None) -> Result:
        kern = kern if kern is not None else pol.kernel
        sources = list(spec.sources)
        if not sources:
            raise ValueError("batched query needs at least one source")
        if pol.mode == "distributed":
            if pol.query_axis == 0:
                return self._run_batched_dist_fallback(
                    spec, pol, p, x0f, pad, apply_kind, post, sources)
            # One 2-D shard_map dispatch: rows over "graph", the query
            # axis over "query" (placement.distributed_sync_run_batched).
            # Bit-identical to the per-source sequential path; `sweeps`
            # is the straggler's, work counters total the query axis.
            # Stack on host: the engine pads/shards the frontier itself,
            # so a device-resident stack would round-trip pointlessly.
            x0 = np.stack([np.asarray(p.to_blocks(x0f(s), pad))
                           for s in sources])
            ekw = dict(apply_kind=apply_kind, damping=pol.damping,
                       tol=pol.tol, max_sweeps=pol.max_sweeps,
                       query_axis=pol.query_axis)
            if pol.dist_flavor == "async":
                from . import async_dist
                x, dist = async_dist.distributed_async_run_batched(
                    p, x0, local_sweeps=pol.local_sweeps, **ekw)
                stats = eng.dist_run_stats(p, dist)
            else:
                from . import placement
                x, dist = placement.distributed_sync_run_batched(
                    p, x0, **ekw)
                stats = eng.bsp_stats(
                    p, dist.sweeps, dist.converged, "distributed",
                    work_sweeps=int(dist.query_sweeps.sum()))
            values = np.stack([post(p.from_blocks(x[q]))
                               for q in range(len(sources))])
            extra = {"algo": spec.algo, "sources": sources, "dist": dist}
            return Result(values, stats, p, extra, policy=pol,
                          graph=self.g)
        x0 = jnp.stack([p.to_blocks(x0f(s), pad) for s in sources])
        kw = dict(apply_kind=apply_kind, damping=pol.damping, tol=pol.tol,
                  max_sweeps=pol.max_sweeps, kernel=kern)
        if pol.mode == "async":
            ch0 = jnp.stack([self._frontier(p, s) for s in sources])
            x, stats = eng.run_async_batched(p, x0, changed0=ch0, **kw)
        else:
            ch0 = (jnp.stack([self._frontier(p, s) for s in sources])
                   if kern.fuse_frontier else None)
            x, stats = eng.run_sync_batched(p, x0, changed0=ch0, **kw)
        values = np.stack([post(p.from_blocks(x[q]))
                           for q in range(len(sources))])
        extra = {"algo": spec.algo, "sources": sources}
        return Result(values, stats, p, extra, policy=pol, graph=self.g)

    def _run_batched_dist_fallback(self, spec, pol, p, x0f, pad,
                                   apply_kind, post, sources) -> Result:
        """``query_axis=0`` escape hatch: the retired per-source loop
        through the sequential distributed engine.  Kept for debugging
        mesh factorizations against a known-serial reference — the
        default batched path is one 2-D shard_map dispatch."""
        xs, sweeps, conv = [], [], []
        for s in sources:
            x0q = p.to_blocks(x0f(s), pad)
            xq, st, _ = self._dispatch(pol, p, x0q, apply_kind, s)
            xs.append(xq)
            sweeps.append(st.sweeps)
            conv.append(st.converged)
        stats = eng.bsp_stats(p, max(sweeps), all(conv),
                              "distributed", work_sweeps=sum(sweeps))
        values = np.stack([post(p.from_blocks(xq)) for xq in xs])
        extra = {"algo": spec.algo, "sources": sources,
                 "batched_fallback": "per-source sequential"}
        return Result(values, stats, p, extra, policy=pol,
                      graph=self.g)

    # -- the algorithm catalog (registry-backed convenience methods) -----

    def _spec(self, algo: str, sources, policy, **params) -> QuerySpec:
        batched = sources is not None and not np.isscalar(sources)
        srcs = (tuple(int(s) for s in sources) if batched
                else ((int(sources),) if sources is not None else ()))
        params = {k: v for k, v in params.items() if v is not None}
        if params:
            base = policy or self.policy.but(
                **dict(get_algorithm(algo).default_policy))
            policy = base.but(**params)
        return QuerySpec(algo=algo, sources=srcs, batched=batched,
                         policy=policy)

    def pagerank(self, damping: Optional[float] = None,
                 tol: Optional[float] = None,
                 max_sweeps: Optional[int] = None,
                 policy: Optional[ExecutionPolicy] = None) -> Result:
        """Convergence kwargs override the (given or session) policy;
        defaults are damping=0.85, tol=1e-8, max_sweeps=500."""
        return self.run(self._spec("pagerank", None, policy,
                                   damping=damping, tol=tol,
                                   max_sweeps=max_sweeps))

    def pagerank_delta(self, damping: Optional[float] = None,
                       tol: Optional[float] = None,
                       max_sweeps: Optional[int] = None,
                       policy: Optional[ExecutionPolicy] = None) -> Result:
        """Delta-accumulating PageRank (GraphScale): ranks only rise from
        the (1-damping)/n floor, making the update idempotent/monotone —
        eligible for the async engine and ``dist_flavor="async"``.
        Tolerance-bounded vs the classic sweep (see algorithm catalog)."""
        return self.run(self._spec("pagerank_delta", None, policy,
                                   damping=damping, tol=tol,
                                   max_sweeps=max_sweeps))

    def sssp(self, sources: Union[int, Sequence[int]],
             policy: Optional[ExecutionPolicy] = None) -> Result:
        """Single-source (int) or batched multi-source (sequence)."""
        return self.run(self._spec("sssp", sources, policy))

    def bfs(self, sources: Union[int, Sequence[int]],
            policy: Optional[ExecutionPolicy] = None) -> Result:
        res = self.run(self._spec("bfs", sources, policy))
        res.extra["levels"] = res.values
        return res

    def connected_components(
            self, policy: Optional[ExecutionPolicy] = None) -> Result:
        return self.run(self._spec("cc", None, policy))

    def kcore(self, k: float,
              policy: Optional[ExecutionPolicy] = None) -> Result:
        """k-core membership: values[v] is 1.0 iff v survives k-core
        peeling.  ``k`` is a required query param (rides the policy's
        damping scalar slot via the registry's param_map)."""
        return self.run(QuerySpec(algo="kcore", policy=policy,
                                  params={"k": float(k)}))

    def reachability(self, src: int,
                     policy: Optional[ExecutionPolicy] = None) -> Result:
        return self.run(self._spec("reachability", src, policy))

    def minitri(self, policy: Optional[ExecutionPolicy] = None,
                chunk: int = 65536) -> Result:
        del policy  # one-shot data-parallel: engine policy does not apply
        return self._minitri(chunk)

    def tricount(self, policy: Optional[ExecutionPolicy] = None,
                 chunk: int = 65536) -> Result:
        """Per-vertex triangle counts (each triangle credits its three
        corners once)."""
        del policy  # one-shot data-parallel: engine policy does not apply
        return self._tricount(chunk)

    def dfs(self, src: int,
            policy: Optional[ExecutionPolicy] = None) -> Result:
        return self.run(QuerySpec(algo="dfs", sources=(int(src),),
                                  policy=policy))

    # -- runner hooks: registry dispatch for non-relaxation workloads ----

    def _minitri_runner(self, spec: QuerySpec,
                        pol: ExecutionPolicy) -> Result:
        return self._minitri()

    def _tricount_runner(self, spec: QuerySpec,
                         pol: ExecutionPolicy) -> Result:
        return self._tricount()

    def _dfs_runner(self, spec: QuerySpec,
                    pol: ExecutionPolicy) -> Result:
        return self._dfs(spec.sources[0])

    # -- triangle workloads: one-shot data-parallel intersections --------

    def _oriented_edges(self):
        """Shared compile-time step for the triangle workloads: orient
        the undirected graph low→high by (degree, id) — a DAG with small
        max out-degree — and return (und, k_max, rows, eu, ev) where
        ``rows`` is the (n+1, k_max) sorted ELL neighbour table padded
        with the sentinel row ``n`` and (eu, ev) are the oriented edges.
        Each triangle appears exactly once: as its lowest edge (u, v)
        with the third corner in N+(u) ∩ N+(v)."""
        und = self._variant("undirected")
        deg = und.out_degrees()
        src = np.repeat(np.arange(und.n, dtype=np.int64),
                        np.diff(und.indptr))
        dst = und.indices.astype(np.int64)
        key_s = deg[src] * (und.n + 1) + src
        key_d = deg[dst] * (und.n + 1) + dst
        keep = key_s < key_d
        s2, d2 = src[keep], dst[keep]
        g_plus = Graph.from_edges(und.n, s2.astype(np.int32),
                                  d2.astype(np.int32),
                                  np.ones(len(s2), dtype=np.float32))
        ell = to_ell_fast(g_plus)
        rows = np.vstack([ell.cols, np.full((1, ell.k_max), und.n,
                                            dtype=np.int32)])
        eu = np.repeat(np.arange(und.n, dtype=np.int32),
                       np.diff(g_plus.indptr))
        ev = g_plus.indices.astype(np.int32)
        return und, ell.k_max, rows, eu, ev

    def _minitri(self, chunk: int = 65536) -> Result:
        und, k_max, rows, eu, ev = self._oriented_edges()
        rows_j = jnp.asarray(rows)
        total = 0
        for i in range(0, len(eu), chunk):
            total += int(_tri_count(rows_j, jnp.asarray(eu[i:i + chunk]),
                                    jnp.asarray(ev[i:i + chunk]),
                                    jnp.int32(und.n)))
        e_plus = len(eu)
        # one-shot data-parallel workload: intersections distribute evenly
        # over the NALE array (no dependency chain), so the critical path
        # is total work / array width, not the serial stream
        nales = 256.0
        stats = RunStats(
            sweeps=1, converged=True,
            tile_work=float(e_plus * k_max),
            edge_work=float(e_plus * max(k_max, 1)),
            crit_tiles=float(e_plus * k_max) / nales,
            active_group_sweeps=nales, halo_tiles=0.0, total_groups=1,
            mode="oneshot")
        return Result(np.array([total]), stats, None,
                      {"algo": "minitri", "triangles": total,
                       "oriented_edges": e_plus, "k_max": k_max},
                      policy=None, graph=self.g)

    def _tricount(self, chunk: int = 65536) -> Result:
        """Per-vertex triangle counts over the same oriented-edge table
        as MiniTri: for each oriented edge (u, v), every common
        out-neighbour w closes one triangle — credit u, v, and w."""
        und, k_max, rows, eu, ev = self._oriented_edges()
        counts = np.zeros(und.n, dtype=np.int64)
        # numpy all-pairs matching per edge chunk; K*K comparisons per
        # edge, chunk sized to bound the (chunk, K, K) mask at ~4M cells
        kk = max(k_max * k_max, 1)
        step = max(1, min(chunk, (1 << 22) // kk))
        for i in range(0, len(eu), step):
            u, v = eu[i:i + step], ev[i:i + step]
            a, b = rows[u], rows[v]               # (E, K) neighbour ids
            m = (a[:, :, None] == b[:, None, :]) & \
                (a[:, :, None] != und.n)
            per_edge = m.sum(axis=(1, 2))
            np.add.at(counts, u, per_edge)
            np.add.at(counts, v, per_edge)
            e_idx, i_idx, _ = np.nonzero(m)
            np.add.at(counts, a[e_idx, i_idx], 1)
        total = int(counts.sum() // 3)
        e_plus = len(eu)
        nales = 256.0
        stats = RunStats(
            sweeps=1, converged=True,
            tile_work=float(e_plus * k_max),
            edge_work=float(e_plus * max(k_max, 1)),
            crit_tiles=float(e_plus * k_max) / nales,
            active_group_sweeps=nales, halo_tiles=0.0, total_groups=1,
            mode="oneshot")
        return Result(counts.astype(np.float32), stats, None,
                      {"algo": "tricount", "triangles": total,
                       "oriented_edges": e_plus, "k_max": k_max},
                      policy=None, graph=self.g)

    # -- DFS: sequential stack machine (worst-case-serial) ---------------

    def _dfs(self, src: int) -> Result:
        g = self.g
        ell = to_ell_fast(g)
        n, k = g.n, ell.k_max
        cols = jnp.asarray(ell.cols)  # pad = n

        cap = g.nnz + n + 2

        @jax.jit
        def run():
            stack = jnp.zeros(cap, dtype=jnp.int32).at[0].set(src)
            pstack = jnp.full(cap, -1, dtype=jnp.int32)
            visited = jnp.zeros(n + 1, dtype=bool).at[n].set(True)
            order = jnp.full(n, -1, dtype=jnp.int32)
            parent = jnp.full(n, -1, dtype=jnp.int32)

            def cond(st):
                sp, *_ = st
                return sp > 0

            def body(st):
                sp, stack, pstack, visited, order, parent, cnt = st
                u = stack[sp - 1]
                pu = pstack[sp - 1]
                sp = sp - 1
                fresh = ~visited[u]

                def visit(args):
                    sp, stack, pstack, visited, order, parent, cnt = args
                    visited = visited.at[u].set(True)
                    order = order.at[cnt].set(u)
                    parent = parent.at[u].set(pu)

                    # push neighbours in reverse so lowest pops first
                    def push(i, a):
                        sp, stack, pstack = a
                        v = cols[u, k - 1 - i]
                        ok = ~visited[v]
                        stack = stack.at[sp].set(
                            jnp.where(ok, v, stack[sp]))
                        pstack = pstack.at[sp].set(
                            jnp.where(ok, u, pstack[sp]))
                        return sp + ok.astype(jnp.int32), stack, pstack

                    sp, stack, pstack = jax.lax.fori_loop(
                        0, k, push, (sp, stack, pstack))
                    return (sp, stack, pstack, visited, order, parent,
                            cnt + 1)

                return jax.lax.cond(
                    fresh, visit, lambda a: a,
                    (sp, stack, pstack, visited, order, parent, cnt))

            st = (jnp.int32(1), stack, pstack, visited, order, parent,
                  jnp.int32(0))
            sp, stack, pstack, visited, order, parent, cnt = \
                jax.lax.while_loop(cond, body, st)
            return order, parent, cnt

        order, parent, cnt = run()
        stats = RunStats(
            sweeps=int(cnt), converged=True,
            tile_work=float(int(cnt) * k), edge_work=float(g.nnz),
            crit_tiles=float(int(cnt) * k),
            active_group_sweeps=float(int(cnt)),
            halo_tiles=0.0, total_groups=1, mode="sequential")
        return Result(np.asarray(order), stats, None,
                      {"algo": "dfs", "src": src,
                       "parent": np.asarray(parent),
                       "visited_count": int(cnt)},
                      policy=None, graph=self.g)


@jax.jit
def _tri_count(rows: jnp.ndarray, eu: jnp.ndarray, ev: jnp.ndarray,
               sentinel: jnp.int32) -> jnp.ndarray:
    """rows: (n+1, k) sorted neighbour ids padded with `sentinel`; (eu, ev)
    oriented edges.  Batched sorted-intersection via searchsorted."""

    def one(u, v):
        a, bb = rows[u], rows[v]
        pos = jnp.searchsorted(bb, a)
        pos = jnp.clip(pos, 0, bb.shape[0] - 1)
        hit = (bb[pos] == a) & (a != sentinel)
        return jnp.sum(hit)

    return jnp.sum(jax.vmap(one)(eu, ev))
