"""Semiring algebra for graph computation — the NALE datapath abstraction.

The paper's NALE (Node Arithmetic Logic Engine) is "optimized for fast MAC
operations with a three-state output comparator".  Algebraically that is a
semiring (⊕, ⊗): the MAC is the ⊗-then-⊕-accumulate, and the three-state
comparator (smaller / equal / larger) is realized by comparing the new
⊕-reduced value against the node's current value, producing both the update
decision and the "changed" bit that feeds the asynchronous frontier.

Semirings implemented (all the paper's six algorithms reduce to these):

  plus_times : (+, ×)  — PageRank, general SpMV
  min_plus   : (min,+) — SSSP, BFS-by-level
  max_min    : (max,min) over {0,1} — boolean or_and reachability
  min_select : (min, select-right) — connected-components label propagation

User-defined semirings register through :func:`register`; the reduction
is a field on the dataclass (with a generic ⊕-fold fallback), so a custom
ring runs through every engine and the reference kernel without touching
dispatch code.

This module also hosts the :class:`UpdateRule` registry — the engine-side
half of an algorithm's identity.  A rule names the apply step (how the
⊕-reduced neighbourhood value ``y`` combines with the node's current
value) and carries the two scheduling properties every engine flavor
keys on:

  bias     — the rule has a constant term (PageRank's (1−d)/n, k-core's
             threshold test), so every valid row must be touched at
             least once even when none of its inputs changed.
  monotone — the update is idempotent and monotone, so a stale input is
             just a not-yet-improved bound; these rules are eligible for
             the self-timed schedules (async engine skipping, the
             distributed ``dist_flavor="async"`` k-local-sweep engine).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax.numpy as jnp
import numpy as np

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class Semiring:
    """An (⊕, ⊗) pair with identities, driving both engines and kernels.

    Attributes:
      name:      stable identifier used for kernel dispatch (static arg).
      add:       ⊕, the reduction (MAC accumulate / comparator side).
      mul:       ⊗, the edge combine (MAC multiply side). mul(edge_w, x_src).
      zero:      ⊕-identity; also the padding value for absent edges, chosen
                 so that padded lanes are no-ops without explicit masks.
      one:       ⊗-identity.
      improves:  strict order test improves(new, old) -> bool array; the
                 "three-state comparator" output used for frontier bits.
      reduce_fn: the axis-reduction realizing ⊕ over an array (e.g.
                 ``jnp.sum`` for plus_times).  None falls back to a
                 generic ⊕-fold of ``add`` — correct for any registered
                 custom semiring, at the cost of XLA seeing a chain of
                 binary ops instead of one fused reduction.
    """

    name: str
    add: Callable[[Array, Array], Array]
    mul: Callable[[Array, Array], Array]
    zero: float
    one: float
    improves: Callable[[Array, Array], Array]
    reduce_fn: Optional[Callable[..., Array]] = None

    def reduce(self, x: Array, axis=None) -> Array:
        if self.reduce_fn is not None:
            return self.reduce_fn(x, axis=axis)
        # generic ⊕-fold: move the reduced axes to one leading axis, then
        # fold ``add`` over its (static) extent.  Works for any custom
        # ring whose ``add`` is associative — no name-switch involved.
        if axis is None:
            axes = tuple(range(x.ndim))
        elif isinstance(axis, int):
            axes = (axis % x.ndim,)
        else:
            axes = tuple(a % x.ndim for a in axis)
        rest = tuple(a for a in range(x.ndim) if a not in axes)
        t = jnp.transpose(x, axes + rest)
        t = t.reshape((-1,) + tuple(x.shape[a] for a in rest))
        out = t[0]
        for i in range(1, t.shape[0]):
            out = self.add(out, t[i])
        return out


def _ne(a, b):
    return a != b


PLUS_TIMES = Semiring(
    name="plus_times",
    add=lambda a, b: a + b,
    mul=lambda w, x: w * x,
    zero=0.0,
    one=1.0,
    improves=_ne,
    reduce_fn=lambda x, axis=None: jnp.sum(x, axis=axis),
)

MIN_PLUS = Semiring(
    name="min_plus",
    add=jnp.minimum,
    mul=lambda w, x: w + x,
    zero=np.inf,
    one=0.0,
    improves=lambda new, old: new < old,
    reduce_fn=lambda x, axis=None: jnp.min(x, axis=axis),
)

MAX_MIN = Semiring(
    name="max_min",
    add=jnp.maximum,
    mul=jnp.minimum,
    zero=0.0,  # valid ⊕-identity for the {0,1} boolean carrier
    one=1.0,
    improves=lambda new, old: new > old,
    reduce_fn=lambda x, axis=None: jnp.max(x, axis=axis),
)

# CC label propagation: edge weight is ignored, the neighbour label is
# selected and min-reduced.  mul(w, x) = x  (select-right).
MIN_SELECT = Semiring(
    name="min_select",
    add=jnp.minimum,
    mul=lambda w, x: x,
    zero=np.inf,
    one=0.0,
    improves=lambda new, old: new < old,
    reduce_fn=lambda x, axis=None: jnp.min(x, axis=axis),
)

SEMIRINGS = {s.name: s for s in (PLUS_TIMES, MIN_PLUS, MAX_MIN, MIN_SELECT)}
# alias: boolean or_and is max_min on the {0,1} carrier
SEMIRINGS["or_and"] = MAX_MIN


def register(ring: Semiring, overwrite: bool = False) -> Semiring:
    """Register a user-defined semiring for engine/kernel dispatch.

    Contract: ``mul(zero, x)`` must equal ``zero`` for every ``x`` (the
    ⊕-identity absorbs, so identity-padded tiles are no-ops without
    masks) and ``add`` must be associative (the generic reduce folds it
    in a fixed but unspecified order).
    """
    if ring.name in SEMIRINGS and not overwrite:
        raise ValueError(
            f"semiring {ring.name!r} is already registered; pass "
            "overwrite=True to replace it")
    SEMIRINGS[ring.name] = ring
    return ring


def get(name: str) -> Semiring:
    try:
        return SEMIRINGS[name]
    except KeyError:
        raise ValueError(f"unknown semiring {name!r}; have {sorted(SEMIRINGS)}")


# ---------------------------------------------------------------------------
# update rules — the engine-facing half of an algorithm's identity
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class UpdateRule:
    """Scheduling properties of one apply rule (``apply_kind``).

    The arithmetic of a rule lives in ``core/engine._apply`` and its
    kernel mirror ``kernels/bsr_spmv._apply_rows``; this record is what
    the *schedulers* consult — no engine string-matches a rule name for
    anything but the arithmetic branch itself.

    Attributes:
      name:     the apply_kind identifier.
      bias:     has a constant term — every valid row must be applied at
                least once even if none of its inputs ever change (the
                fused sync loop's sweep-0 all-rows touch, the async
                engine's first-touch activation).
      monotone: idempotent + monotone — stale inputs are conservative
                bounds, so the rule is eligible for self-timed schedules
                (async cluster skipping, ``dist_flavor="async"``).
      exact:    schedule-independent at convergence — converged states
                are bit-identical across engine flavors (vs. tolerance-
                bounded for accumulation rules, where grouping of float
                adds differs between schedules).
    """

    name: str
    bias: bool
    monotone: bool
    exact: bool


UPDATE_RULES = {r.name: r for r in (
    # x' = y ⊕ x: the semiring relaxation (SSSP/BFS/CC/reachability).
    UpdateRule("relax", bias=False, monotone=True, exact=True),
    # x' = (1−d)/n + d·y, unconditional: classic damped PageRank sweep.
    # Order-sensitive (a stale y is not a bound) — sync schedules only.
    UpdateRule("pagerank", bias=True, monotone=False, exact=False),
    # x' = max(x, (1−d)/n + d·y): delta-accumulating PageRank
    # (GraphScale's async formulation).  Starting from x0 = (1−d)/n the
    # iterates increase monotonically to the same unique fixpoint, and
    # the conditional assignment makes the rule idempotent — stale reads
    # are under-estimates, so it is self-timed-eligible.  bias=False:
    # a row with no in-edges is *born* converged at (1−d)/n.
    UpdateRule("pagerank_delta", bias=False, monotone=True, exact=False),
    # x' = x if (x > 0 and y ≥ k) else 0: k-core membership peeling over
    # unit weights (y counts live neighbours; k rides the damping
    # scalar slot).  Monotone-decreasing on {0,1} — stale reads over-
    # estimate liveness, conservatively — and bit-exact everywhere.
    # bias=True: a vertex with no in-edges must be touched once to die.
    UpdateRule("kcore", bias=True, monotone=True, exact=True),
    # x' = y: plain SpMV assignment (debug/diagnostic).
    UpdateRule("identity", bias=True, monotone=False, exact=False),
)}


def register_rule(r: UpdateRule, overwrite: bool = False) -> UpdateRule:
    if r.name in UPDATE_RULES and not overwrite:
        raise ValueError(
            f"update rule {r.name!r} is already registered; pass "
            "overwrite=True to replace it")
    UPDATE_RULES[r.name] = r
    return r


def rule(name: str) -> UpdateRule:
    try:
        return UPDATE_RULES[name]
    except KeyError:
        raise ValueError(
            f"unknown update rule {name!r}; have {sorted(UPDATE_RULES)}")
