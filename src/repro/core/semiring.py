"""Semiring algebra for graph computation — the NALE datapath abstraction.

The paper's NALE (Node Arithmetic Logic Engine) is "optimized for fast MAC
operations with a three-state output comparator".  Algebraically that is a
semiring (⊕, ⊗): the MAC is the ⊗-then-⊕-accumulate, and the three-state
comparator (smaller / equal / larger) is realized by comparing the new
⊕-reduced value against the node's current value, producing both the update
decision and the "changed" bit that feeds the asynchronous frontier.

Semirings implemented (all the paper's six algorithms reduce to these):

  plus_times : (+, ×)  — PageRank, general SpMV
  min_plus   : (min,+) — SSSP, BFS-by-level
  max_min    : (max,min) over {0,1} — boolean or_and reachability
  min_select : (min, select-right) — connected-components label propagation
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp
import numpy as np

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class Semiring:
    """An (⊕, ⊗) pair with identities, driving both engines and kernels.

    Attributes:
      name:      stable identifier used for kernel dispatch (static arg).
      add:       ⊕, the reduction (MAC accumulate / comparator side).
      mul:       ⊗, the edge combine (MAC multiply side). mul(edge_w, x_src).
      zero:      ⊕-identity; also the padding value for absent edges, chosen
                 so that padded lanes are no-ops without explicit masks.
      one:       ⊗-identity.
      improves:  strict order test improves(new, old) -> bool array; the
                 "three-state comparator" output used for frontier bits.
    """

    name: str
    add: Callable[[Array, Array], Array]
    mul: Callable[[Array, Array], Array]
    zero: float
    one: float
    improves: Callable[[Array, Array], Array]

    def reduce(self, x: Array, axis=None) -> Array:
        if self.name == "plus_times":
            return jnp.sum(x, axis=axis)
        if self.name == "min_plus" or self.name == "min_select":
            return jnp.min(x, axis=axis)
        if self.name == "max_min":
            return jnp.max(x, axis=axis)
        raise ValueError(f"unknown semiring {self.name}")


def _ne(a, b):
    return a != b


PLUS_TIMES = Semiring(
    name="plus_times",
    add=lambda a, b: a + b,
    mul=lambda w, x: w * x,
    zero=0.0,
    one=1.0,
    improves=_ne,
)

MIN_PLUS = Semiring(
    name="min_plus",
    add=jnp.minimum,
    mul=lambda w, x: w + x,
    zero=np.inf,
    one=0.0,
    improves=lambda new, old: new < old,
)

MAX_MIN = Semiring(
    name="max_min",
    add=jnp.maximum,
    mul=jnp.minimum,
    zero=0.0,  # valid ⊕-identity for the {0,1} boolean carrier
    one=1.0,
    improves=lambda new, old: new > old,
)

# CC label propagation: edge weight is ignored, the neighbour label is
# selected and min-reduced.  mul(w, x) = x  (select-right).
MIN_SELECT = Semiring(
    name="min_select",
    add=jnp.minimum,
    mul=lambda w, x: x,
    zero=np.inf,
    one=0.0,
    improves=lambda new, old: new < old,
)

SEMIRINGS = {s.name: s for s in (PLUS_TIMES, MIN_PLUS, MAX_MIN, MIN_SELECT)}
# alias: boolean or_and is max_min on the {0,1} carrier
SEMIRINGS["or_and"] = MAX_MIN


def get(name: str) -> Semiring:
    try:
        return SEMIRINGS[name]
    except KeyError:
        raise ValueError(f"unknown semiring {name!r}; have {sorted(SEMIRINGS)}")
