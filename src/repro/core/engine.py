"""Graph execution engines — the paper's model of computation, in JAX.

Two engines over the same clustered BSR substrate:

  * ``run_sync``  — bulk-synchronous (Jacobi): every sweep processes every
    tile against last sweep's values.  This is the conventional
    global-clock execution the paper argues against; it is the CPU/GPU
    baseline semantics.

  * ``run_async`` — the paper's asynchronous model, adapted to TPU (see
    DESIGN.md §2): clusters are processed along the dependency schedule;
    each cluster (a) *skips* entirely when none of its inputs changed —
    self-timed, work ∝ data readiness — and (b) reads the *freshest*
    values, including ones produced earlier in the same sweep
    (Gauss-Seidel), the software analogue of values flowing through NALE
    FIFOs as soon as they are produced rather than at a global barrier.

Both engines emit work counters (tiles, edges, per-sweep critical path,
halo traffic) that feed the cycle/energy models in ``power.py`` and the
ISA-level accounting in ``compile.py``.
"""

from __future__ import annotations

import dataclasses
import functools
import hashlib
import io
import json
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import semiring as sr
from .cluster import Clustering, cluster_graph, identity_clustering
from .graph import Graph, to_bsr
from ..kernels import ops
from ..kernels.spec import KernelSpec, as_kernel_spec
from .. import resilience


@dataclasses.dataclass
class Prepared:
    """Clustered, permuted, device-resident graph + engine metadata."""

    # device arrays
    vals: jnp.ndarray       # (r_pad, K, B, B) f32
    cols: jnp.ndarray       # (r_pad, K) i32
    nnz: jnp.ndarray        # (r_pad,) i32
    valid: jnp.ndarray      # (r_pad, B) bool — real (non-padding) vertices
    dangling: jnp.ndarray   # (r_pad, B) bool — zero-outdegree vertices
    group_tiles: jnp.ndarray  # (S,) f32
    group_edges: jnp.ndarray  # (S,) f32
    group_ext_tiles: jnp.ndarray  # (S,) f32 — tiles reading outside group
    row_edges: jnp.ndarray  # (r_pad,) f32 — true edges per row-block
    row_ext: jnp.ndarray    # (r_pad,) f32 — tiles reading outside the
    #                         row's group (fused-path halo accounting)
    # host metadata
    n: int
    b: int
    r_pad: int
    k_max: int
    gb: int                 # row-blocks per group ("cluster" at engine level)
    s: int                  # number of groups
    semiring: str
    perm: np.ndarray        # old id -> new id
    inv_perm: np.ndarray    # new id -> old id
    clustering: Clustering
    tiles_total: float = 0.0
    edges_total: float = 0.0

    def to_blocks(self, x_flat: np.ndarray, pad: float) -> jnp.ndarray:
        """(n,) values in OLD ids → (r_pad, B) block layout in new ids."""
        out = np.full(self.r_pad * self.b, pad, dtype=np.float32)
        out[self.perm] = x_flat
        return jnp.asarray(out.reshape(self.r_pad, self.b))

    def from_blocks(self, xb: jnp.ndarray) -> np.ndarray:
        """(r_pad, B) block layout → (n,) values in OLD ids."""
        flat = np.asarray(xb).reshape(-1)
        return flat[self.perm]

    @property
    def nbytes(self) -> int:
        """Footprint of the plan (device tile image + host metadata) —
        the unit of the plan store's byte budget.  Metadata-only: jax
        arrays report nbytes without a device-to-host transfer."""
        dev = sum(int(getattr(self, f).nbytes)
                  for f in _PREPARED_DEVICE_FIELDS)
        host = int(self.perm.nbytes) + int(self.inv_perm.nbytes) + \
            int(self.clustering.assign.nbytes) + \
            int(self.clustering.perm.nbytes)
        return dev + host


# ``Prepared`` as a pytree: device arrays are leaves, host metadata is the
# (hashable, content-compared) treedef aux.  This is what makes a plan a
# first-class JAX value — it can ride through jax.tree_util (serialization
# walks the same split) and be passed whole into transformed functions.

_PREPARED_DEVICE_FIELDS = (
    "vals", "cols", "nnz", "valid", "dangling",
    "group_tiles", "group_edges", "group_ext_tiles",
    "row_edges", "row_ext")
_PREPARED_HOST_FIELDS = (
    "n", "b", "r_pad", "k_max", "gb", "s", "semiring",
    "perm", "inv_perm", "clustering", "tiles_total", "edges_total")


class _HostMeta:
    """Hashable wrapper for Prepared's host half (numpy arrays compare by
    content; the hash folds in the permutation bytes)."""

    __slots__ = ("fields", "_hash")

    def __init__(self, fields: tuple):
        self.fields = fields
        d = dict(zip(_PREPARED_HOST_FIELDS, fields))
        self._hash = hash((d["n"], d["b"], d["r_pad"], d["k_max"],
                           d["gb"], d["s"], d["semiring"],
                           d["perm"].tobytes()))

    def __hash__(self):
        return self._hash

    def __eq__(self, other):
        if not isinstance(other, _HostMeta):
            return NotImplemented
        for a, b in zip(self.fields, other.fields):
            if isinstance(a, np.ndarray):
                if not np.array_equal(a, b):
                    return False
            elif isinstance(a, Clustering):
                if not (a.num_clusters == b.num_clusters
                        and np.array_equal(a.perm, b.perm)
                        and np.array_equal(a.schedule, b.schedule)):
                    return False
            elif a != b:
                return False
        return True


def _prepared_flatten(p: Prepared):
    children = tuple(getattr(p, f) for f in _PREPARED_DEVICE_FIELDS)
    aux = _HostMeta(tuple(getattr(p, f) for f in _PREPARED_HOST_FIELDS))
    return children, aux


def _prepared_unflatten(aux: _HostMeta, children) -> Prepared:
    kw = dict(zip(_PREPARED_DEVICE_FIELDS, children))
    kw.update(zip(_PREPARED_HOST_FIELDS, aux.fields))
    return Prepared(**kw)


jax.tree_util.register_pytree_node(
    Prepared, _prepared_flatten, _prepared_unflatten)


# ---------------------------------------------------------------------------
# Prepared (de)serialization — the persistent half of the plan store
# ---------------------------------------------------------------------------
#
# A serialized plan is one .npz payload: the device tile image pulled back
# to host, the clustering/permutation, and a JSON metadata record.  A warm
# restart deserializes this instead of re-running the whole compile
# pipeline (profile → cluster → analyze → place → BSR build).

PREPARED_FORMAT_VERSION = 2  # v2: + row_edges/row_ext (fused-path counters)

# Payload framing: serialized plans carry a content digest so the store
# can tell a corrupt/truncated disk entry from a healthy one and
# quarantine-and-rebuild instead of crashing (or worse, loading silently
# mangled tiles).  Frame = MAGIC + blake2b-128(payload) + payload;
# pre-framing payloads (no magic) still load, with integrity unknown.
_PLAN_MAGIC = b"RPLN\x01\x00"
_PLAN_DIGEST_SIZE = 16


class PlanIntegrityError(ValueError):
    """A framed plan payload failed its checksum — the bytes on disk are
    not the bytes that were written (bit rot, truncation, torn write)."""


def _frame_payload(payload: bytes) -> bytes:
    digest = hashlib.blake2b(payload,
                             digest_size=_PLAN_DIGEST_SIZE).digest()
    return _PLAN_MAGIC + digest + payload


def _unframe_payload(data: bytes) -> bytes:
    if not data.startswith(_PLAN_MAGIC):
        return data  # legacy unframed payload
    head = len(_PLAN_MAGIC)
    digest = data[head:head + _PLAN_DIGEST_SIZE]
    payload = data[head + _PLAN_DIGEST_SIZE:]
    want = hashlib.blake2b(payload,
                           digest_size=_PLAN_DIGEST_SIZE).digest()
    if digest != want:
        raise PlanIntegrityError(
            f"plan payload checksum mismatch ({len(payload)} bytes); "
            "the disk entry is corrupt — rebuild the plan")
    return payload


def serialize_prepared(p: Prepared) -> bytes:
    """Pack a ``Prepared`` into a self-describing bytes payload."""
    c = p.clustering
    meta = dict(
        version=PREPARED_FORMAT_VERSION, n=p.n, b=p.b, r_pad=p.r_pad,
        k_max=p.k_max, gb=p.gb, s=p.s, semiring=p.semiring,
        tiles_total=p.tiles_total, edges_total=p.edges_total,
        c_num_clusters=c.num_clusters, c_internal=c.internal_edges,
        c_cut=c.cut_edges)
    arrays = {f: np.asarray(getattr(p, f)) for f in _PREPARED_DEVICE_FIELDS}
    arrays.update(perm=p.perm, inv_perm=p.inv_perm, c_assign=c.assign,
                  c_perm=c.perm, c_sizes=c.sizes, c_schedule=c.schedule)
    buf = io.BytesIO()
    np.savez(buf, __meta__=np.frombuffer(
        json.dumps(meta).encode(), dtype=np.uint8), **arrays)
    return _frame_payload(buf.getvalue())


def deserialize_prepared(data: bytes) -> Prepared:
    """Rebuild a ``Prepared`` (device arrays re-uploaded) from a payload
    produced by :func:`serialize_prepared`.  Raises
    ``PlanIntegrityError`` when a framed payload fails its checksum."""
    data = _unframe_payload(data)
    with np.load(io.BytesIO(data), allow_pickle=False) as z:
        meta = json.loads(z["__meta__"].tobytes().decode())
        if meta["version"] != PREPARED_FORMAT_VERSION:
            raise ValueError(
                f"plan payload version {meta['version']} != "
                f"{PREPARED_FORMAT_VERSION}; rebuild the plan")
        arrays = {k: z[k] for k in z.files if k != "__meta__"}
    clustering = Clustering(
        num_clusters=int(meta["c_num_clusters"]),
        assign=arrays["c_assign"], perm=arrays["c_perm"],
        sizes=arrays["c_sizes"], schedule=arrays["c_schedule"],
        internal_edges=int(meta["c_internal"]),
        cut_edges=int(meta["c_cut"]))
    return Prepared(
        **{f: jnp.asarray(arrays[f]) for f in _PREPARED_DEVICE_FIELDS},
        n=int(meta["n"]), b=int(meta["b"]), r_pad=int(meta["r_pad"]),
        k_max=int(meta["k_max"]), gb=int(meta["gb"]), s=int(meta["s"]),
        semiring=meta["semiring"], perm=arrays["perm"],
        inv_perm=arrays["inv_perm"], clustering=clustering,
        tiles_total=float(meta["tiles_total"]),
        edges_total=float(meta["edges_total"]))


def prepare(g: Graph, semiring_name: str, b: int = 32,
            num_clusters: Optional[int] = None, pull: bool = True,
            clustered: bool = True, normalize: Optional[str] = None,
            seed: int = 0) -> Prepared:
    """Paper Fig. 4 steps 1–5: profile/extract → cluster → analyze →
    place → build the device BSR image.

    pull=True computes over in-edges (y_i = ⊕_j A[j→i] ⊗ x_j), the natural
    direction for relaxation/propagation algorithms.
    normalize="out_stochastic": edge j→i gets weight 1/outdeg(j) (PageRank).
    """
    ring = sr.get(semiring_name)
    n = g.n
    if normalize == "out_stochastic":
        outdeg = np.maximum(np.diff(g.indptr), 1)
        w = (1.0 / outdeg)[np.repeat(np.arange(n), np.diff(g.indptr))]
        g = Graph(n=n, indptr=g.indptr, indices=g.indices,
                  weights=w.astype(np.float32))
    num_clusters = num_clusters or max(1, min(64, n // max(b, 1)))
    c = (cluster_graph(g, num_clusters, seed=seed) if clustered
         else identity_clustering(g, num_clusters))
    g2 = g.permute(c.perm.astype(np.int32))
    gm = g2.transpose() if pull else g2
    bsr = to_bsr(gm, b, pad_value=float(ring.zero))

    # group (engine-level cluster) geometry: contiguous row-block ranges
    s = min(c.num_clusters, bsr.r)
    gb = (bsr.r + s - 1) // s
    r_pad = s * gb
    k = bsr.k_max
    vals = np.full((r_pad, k, b, b), float(ring.zero), dtype=np.float32)
    cols = np.zeros((r_pad, k), dtype=np.int32)
    nnz = np.zeros(r_pad, dtype=np.int32)
    vals[: bsr.r] = bsr.block_vals
    cols[: bsr.r] = bsr.block_cols
    nnz[: bsr.r] = bsr.block_nnz

    valid = np.zeros((r_pad, b), dtype=bool)
    valid.reshape(-1)[: n] = True  # permuted ids are 0..n-1
    outdeg0 = np.zeros(r_pad * b, dtype=np.int64)
    outdeg0[: n] = np.diff(g2.indptr)
    dangling = valid & (outdeg0.reshape(r_pad, b) == 0)

    grp = np.arange(r_pad) // gb
    group_tiles = np.zeros(s, dtype=np.float64)
    np.add.at(group_tiles, grp, nnz)
    group_edges = np.zeros(s, dtype=np.float64)
    edge_nnz = np.zeros(r_pad, dtype=np.float64)
    edge_nnz[: bsr.r] = bsr.edge_nnz
    np.add.at(group_edges, grp, edge_nnz)
    # halo: tiles whose source col-block lives outside the group row range
    ext = ((cols // gb) != grp[:, None]) & \
          (np.arange(k)[None, :] < nnz[:, None])
    group_ext_tiles = np.zeros(s, dtype=np.float64)
    np.add.at(group_ext_tiles, grp, ext.sum(axis=1))
    row_ext = ext.sum(axis=1).astype(np.float64)

    return Prepared(
        vals=jnp.asarray(vals), cols=jnp.asarray(cols), nnz=jnp.asarray(nnz),
        valid=jnp.asarray(valid), dangling=jnp.asarray(dangling),
        group_tiles=jnp.asarray(group_tiles, jnp.float32),
        group_edges=jnp.asarray(group_edges, jnp.float32),
        group_ext_tiles=jnp.asarray(group_ext_tiles, jnp.float32),
        row_edges=jnp.asarray(edge_nnz, jnp.float32),
        row_ext=jnp.asarray(row_ext, jnp.float32),
        n=n, b=b, r_pad=r_pad, k_max=k, gb=gb, s=s,
        semiring=semiring_name, perm=np.asarray(c.perm),
        inv_perm=np.argsort(np.asarray(c.perm)), clustering=c,
        tiles_total=float(nnz.sum()), edges_total=float(edge_nnz.sum()))


# ---------------------------------------------------------------------------
# apply / convergence rules
# ---------------------------------------------------------------------------


def _apply(apply_kind: str, ring: sr.Semiring, y, xg, valid_g, damping,
           inv_n, tol):
    """Returns (x_new, improved_rows) for one block of rows.

    Note: PageRank uses dangling-drop semantics (no global dangling-mass
    redistribution; the result is L1-renormalized by the caller).  This
    keeps the update *edge-local*, which the asynchronous model requires —
    a global scalar input would invalidate cluster-level data-readiness
    tracking (and is exactly the kind of global synchronization the paper's
    architecture removes).
    """
    if apply_kind == "relax":
        x_new = ring.add(y, xg)
        imp = ring.improves(x_new, xg)
    elif apply_kind == "pagerank":
        x_new = (1.0 - damping) * inv_n + damping * y
        x_new = jnp.where(valid_g, x_new, 0.0)
        imp = jnp.abs(x_new - xg) > tol
    elif apply_kind == "pagerank_delta":
        # GraphScale's delta form: ranks only RISE (by > tol) from the
        # (1-d)/n floor toward the fixpoint — conditional assignment
        # makes the rule idempotent + monotone, so it is safe under
        # every self-timed schedule (stale y under-estimates the rank).
        cand = (1.0 - damping) * inv_n + damping * y
        imp = (cand - xg) > tol
        x_new = jnp.where(imp, cand, xg)
    elif apply_kind == "kcore":
        # membership peeling: y counts live neighbours (plus_times over
        # unit weights); k rides the damping scalar slot.  Monotone-
        # decreasing on {0,1} — a vertex dies when its live-degree
        # drops below k and never revives.
        alive = (xg > 0.0) & (y >= damping)
        x_new = jnp.where(alive, xg, 0.0)
        imp = x_new < xg
    elif apply_kind == "identity":
        x_new = jnp.where(valid_g, y, xg)
        imp = ring.improves(x_new, xg)
    else:
        raise ValueError(apply_kind)
    x_new = jnp.where(valid_g, x_new, xg)
    imp = imp & valid_g
    return x_new, imp


@dataclasses.dataclass
class RunStats:
    sweeps: int
    converged: bool
    tile_work: float          # tiles actually combined
    edge_work: float          # true edges behind those tiles
    crit_tiles: float         # Σ_sweeps max_cluster(active tiles) — NALE critical path
    active_group_sweeps: float
    halo_tiles: float         # inter-cluster tile reads (FIFO/ICI traffic)
    total_groups: int
    mode: str


def bsp_stats(p: Prepared, sweeps: int, converged: bool, mode: str,
              work_sweeps: Optional[int] = None) -> RunStats:
    """Work counters for bulk-synchronous execution: every sweep touches
    every tile.  ``work_sweeps`` (default ``sweeps``) lets batched runs
    charge total work across the query axis while ``sweeps`` (and the
    critical path) reflect the straggler query."""
    w = sweeps if work_sweeps is None else work_sweeps
    return RunStats(
        sweeps=sweeps, converged=converged,
        tile_work=p.tiles_total * w,
        edge_work=p.edges_total * w,
        crit_tiles=float(np.max(np.asarray(p.group_tiles))) * sweeps,
        active_group_sweeps=float(p.s * w),
        halo_tiles=float(np.asarray(p.group_ext_tiles).sum()) * w,
        total_groups=p.s, mode=mode)


def dist_run_stats(p: Prepared, dist, mode: str = "distributed"
                   ) -> RunStats:
    """Work counters for a distributed run described by a
    ``placement.DistStats``.  Compute work follows the sweep counts as in
    :func:`bsp_stats`, but halo traffic is charged per *exchange*: the
    self-timed flavor's entire point is ``halo_exchanges < sweeps`` when
    ``local_sweeps > 1``, and the modeled boundary traffic must show it.
    """
    qs = dist.query_sweeps
    w = int(qs.sum()) if qs is not None else int(dist.sweeps)
    return RunStats(
        sweeps=dist.sweeps, converged=dist.converged,
        tile_work=p.tiles_total * w,
        edge_work=p.edges_total * w,
        crit_tiles=float(np.max(np.asarray(p.group_tiles))) * dist.sweeps,
        active_group_sweeps=float(p.s * w),
        halo_tiles=float(np.asarray(p.group_ext_tiles).sum())
        * dist.halo_exchanges,
        total_groups=p.s, mode=mode)


# ---------------------------------------------------------------------------
# synchronous (BSP / Jacobi) engine
# ---------------------------------------------------------------------------


def _resolve_kernel(kernel, impl: str) -> KernelSpec:
    """Resolve the runner-level ``kernel=``/legacy ``impl=`` pair into
    one KernelSpec (``kernel`` wins when given)."""
    if kernel is not None:
        return as_kernel_spec(kernel)
    return KernelSpec(impl=impl)


@functools.partial(jax.jit, static_argnames=(
    "semiring_name", "apply_kind", "max_sweeps", "kernel"))
def _sync_loop(vals, cols, nnz, valid, dangling, x0, damping, tol, inv_n,
               semiring_name, apply_kind, max_sweeps, kernel):
    ring = sr.get(semiring_name)
    spmv = ops.select_kernel("bsr_spmv", kernel)

    def cond(st):
        i, x, done = st
        return (~done) & (i < max_sweeps)

    def body(st):
        i, x, _ = st
        y = spmv(vals, cols, nnz, x, semiring=semiring_name)
        x_new, imp = _apply(apply_kind, ring, y, x, valid, damping, inv_n,
                            tol)
        return i + 1, x_new, ~jnp.any(imp)

    i, x, done = jax.lax.while_loop(cond, body, (jnp.int32(0), x0, False))
    return i, x, done


@functools.partial(jax.jit, static_argnames=(
    "semiring_name", "apply_kind", "max_sweeps", "gb", "s", "kernel"))
def _sync_loop_fused(vals, cols, nnz, valid, row_edges, row_ext, x0,
                     changed0, damping, tol, inv_n, semiring_name,
                     apply_kind, max_sweeps, gb, s, kernel):
    """Jacobi sweep via the fused kernel: each sweep builds the active
    row-block set from the change flags (a row is live iff one of its
    live input tiles changed last sweep), hands the compact list to the
    fused relax+select+reduce kernel, and consumes the kernel's own
    convergence flag — no separate XLA apply/reduce.

    Exactness: with ``act`` built this way, skipped rows provably cannot
    improve (their inputs are bitwise-unchanged), so the trajectory —
    values AND sweep count — matches the unfused path.  Bias apply kinds
    (pagerank/identity) must touch every valid row once, on sweep 0.
    """
    spmv = ops.select_kernel("bsr_spmv", kernel)
    k = cols.shape[1]
    lane = jnp.arange(k)[None, :]
    live = lane < nnz[:, None]
    nnz_f = nnz.astype(jnp.float32)
    bias = sr.rule(apply_kind).bias
    valid_rows = jnp.any(valid, axis=1)

    def cond(st):
        i, x, ch, done, c = st
        return (~done) & (i < max_sweeps)

    def body(st):
        i, x, ch, _, c = st
        act = jnp.any(ch[cols] & live, axis=1)
        if bias:
            act = act | ((i == 0) & valid_rows)
        x, ch, imp_any = spmv(vals, cols, nnz, x, x, valid, act, damping,
                              tol, inv_n, semiring=semiring_name,
                              apply_kind=apply_kind)
        af = act.astype(jnp.float32)
        g_tiles = (af * nnz_f).reshape(s, gb).sum(axis=1)
        c = dict(
            c,
            tile_work=c["tile_work"] + jnp.sum(af * nnz_f),
            edge_work=c["edge_work"] + jnp.sum(af * row_edges),
            halo=c["halo"] + jnp.sum(af * row_ext),
            active=c["active"] + jnp.sum(
                jnp.any(act.reshape(s, gb), axis=1).astype(jnp.float32)),
            crit=c["crit"] + jnp.max(g_tiles))
        return i + 1, x, ch, ~imp_any, c

    counters0 = dict(tile_work=jnp.float32(0), edge_work=jnp.float32(0),
                     halo=jnp.float32(0), active=jnp.float32(0),
                     crit=jnp.float32(0))
    i, x, ch, done, c = jax.lax.while_loop(
        cond, body, (jnp.int32(0), x0, changed0, False, counters0))
    return i, x, done, c


def _counter_stats(p: Prepared, sweeps: int, converged: bool, c: dict,
                   mode: str) -> RunStats:
    """RunStats from measured per-sweep counters (fused paths); batched
    callers pass summed arrays, so reduce with numpy."""
    return RunStats(
        sweeps=sweeps, converged=converged,
        tile_work=float(np.asarray(c["tile_work"]).sum()),
        edge_work=float(np.asarray(c["edge_work"]).sum()),
        crit_tiles=float(np.asarray(c["crit"]).max(initial=0.0)),
        active_group_sweeps=float(np.asarray(c["active"]).sum()),
        halo_tiles=float(np.asarray(c["halo"]).sum()),
        total_groups=p.s, mode=mode)


def run_sync(p: Prepared, x0: jnp.ndarray, apply_kind: str = "relax",
             damping: float = 0.85, tol: float = 1e-6,
             max_sweeps: int = 10_000, impl: str = "ref", kernel=None,
             changed0: Optional[jnp.ndarray] = None
             ) -> Tuple[jnp.ndarray, RunStats]:
    spec = _resolve_kernel(kernel, impl)
    resilience.fire("engine.run", mode="sync", impl=spec.impl,
                    fused=spec.fuse_frontier, batched=False)
    inv_n = jnp.float32(1.0 / max(p.n, 1))
    if spec.fuse_frontier:
        if changed0 is None:
            changed0 = jnp.ones(p.r_pad, dtype=bool)
        i, x, done, c = _sync_loop_fused(
            p.vals, p.cols, p.nnz, p.valid, p.row_edges, p.row_ext, x0,
            changed0, jnp.float32(damping), jnp.float32(tol), inv_n,
            p.semiring, apply_kind, max_sweeps, p.gb, p.s, spec)
        return x, _counter_stats(p, int(i), bool(done), c, "sync")
    i, x, done = _sync_loop(p.vals, p.cols, p.nnz, p.valid, p.dangling, x0,
                            jnp.float32(damping), jnp.float32(tol), inv_n,
                            p.semiring, apply_kind, max_sweeps, spec)
    return x, bsp_stats(p, int(i), bool(done), "sync")


# ---------------------------------------------------------------------------
# asynchronous (cluster-dataflow, Gauss-Seidel) engine
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=(
    "semiring_name", "apply_kind", "max_sweeps", "gb", "s", "kernel"))
def _async_loop(vals, cols, nnz, valid, dangling, group_tiles, group_edges,
                group_ext, row_edges, row_ext, x0, changed0, damping, tol,
                inv_n, semiring_name, apply_kind, max_sweeps, gb, s,
                kernel):
    ring = sr.get(semiring_name)
    spmv = ops.select_kernel("bsr_spmv", kernel)
    fused = kernel.fuse_frontier
    k = cols.shape[1]
    lane = jnp.arange(k)[None, :]

    # apply kinds with a bias term (PageRank's (1-d)/n, k-core's
    # threshold test) must touch every cluster at least once even if it
    # has no in-edges (registry: semiring.UPDATE_RULES).
    first_touch = sr.rule(apply_kind).bias

    def sweep_step(carry, sidx):
        x, ch_prev, ch_next, ran, counters = carry
        row0 = sidx * gb
        vals_g = jax.lax.dynamic_slice_in_dim(vals, row0, gb, 0)
        cols_g = jax.lax.dynamic_slice_in_dim(cols, row0, gb, 0)
        nnz_g = jax.lax.dynamic_slice_in_dim(nnz, row0, gb, 0)
        # data readiness: any live input tile whose source block changed —
        # either last sweep (ch_prev) or earlier THIS sweep (ch_next, the
        # Gauss-Seidel freshness path).
        ch = ch_prev | ch_next
        live = lane < nnz_g[:, None]
        active = jnp.any(ch[cols_g] & live)
        if first_touch:
            active = active | ~ran[sidx]
        if fused:
            # row-granular frontier inside the group: the kernel's active
            # list skips the group's untouched row-blocks entirely.
            vg = jax.lax.dynamic_slice_in_dim(valid, row0, gb, 0)
            act_rows = jnp.any(ch[cols_g] & live, axis=1)
            if first_touch:
                act_rows = act_rows | (~ran[sidx] & jnp.any(vg, axis=1))

        def do(args):
            x, ch_next = args
            xg = jax.lax.dynamic_slice_in_dim(x, row0, gb, 0)
            vg = jax.lax.dynamic_slice_in_dim(valid, row0, gb, 0)
            if fused:
                x_new, imp_rows, _ = spmv(
                    vals_g, cols_g, nnz_g, x, xg, vg, act_rows, damping,
                    tol, inv_n, semiring=semiring_name,
                    apply_kind=apply_kind)
            else:
                y = spmv(vals_g, cols_g, nnz_g, x, semiring=semiring_name)
                x_new, imp = _apply(apply_kind, ring, y, xg, vg, damping,
                                    inv_n, tol)
                imp_rows = jnp.any(imp, axis=1)
            x = jax.lax.dynamic_update_slice_in_dim(x, x_new, row0, 0)
            ch_next = jax.lax.dynamic_update_slice_in_dim(
                ch_next, imp_rows, row0, 0)
            return x, ch_next

        x, ch_next = jax.lax.cond(active, do, lambda a: a, (x, ch_next))
        ran = ran.at[sidx].set(ran[sidx] | active)
        af = active.astype(jnp.float32)
        if fused:
            # charge only the rows the kernel actually walked
            arf = act_rows.astype(jnp.float32)
            g_tiles = jnp.sum(arf * nnz_g.astype(jnp.float32))
            g_edges = jnp.sum(
                arf * jax.lax.dynamic_slice_in_dim(row_edges, row0, gb, 0))
            g_halo = jnp.sum(
                arf * jax.lax.dynamic_slice_in_dim(row_ext, row0, gb, 0))
        else:
            g_tiles = af * group_tiles[sidx]
            g_edges = af * group_edges[sidx]
            g_halo = af * group_ext[sidx]
        counters = dict(
            counters,
            tile_work=counters["tile_work"] + g_tiles,
            edge_work=counters["edge_work"] + g_edges,
            halo=counters["halo"] + g_halo,
            active=counters["active"] + af,
            sweep_max=jnp.maximum(counters["sweep_max"], g_tiles))
        return (x, ch_prev, ch_next, ran, counters), None

    def cond(st):
        i, x, ch, ran, done, _ = st
        return (~done) & (i < max_sweeps)

    def body(st):
        i, x, ch_prev, ran, _, counters = st
        counters = dict(counters, sweep_max=jnp.float32(0.0))
        ch_next = jnp.zeros_like(ch_prev)
        (x, _, ch_next, ran, counters), _ = jax.lax.scan(
            sweep_step, (x, ch_prev, ch_next, ran, counters),
            jnp.arange(s, dtype=jnp.int32))
        counters = dict(counters,
                        crit=counters["crit"] + counters["sweep_max"])
        done = ~jnp.any(ch_next)
        return i + 1, x, ch_next, ran, done, counters

    counters0 = dict(tile_work=jnp.float32(0), edge_work=jnp.float32(0),
                     halo=jnp.float32(0), active=jnp.float32(0),
                     crit=jnp.float32(0), sweep_max=jnp.float32(0))
    ran0 = jnp.zeros(s, dtype=bool)
    i, x, ch, ran, done, counters = jax.lax.while_loop(
        cond, body, (jnp.int32(0), x0, changed0, ran0, False, counters0))
    return i, x, done, counters


def run_async(p: Prepared, x0: jnp.ndarray, apply_kind: str = "relax",
              damping: float = 0.85, tol: float = 1e-6,
              max_sweeps: int = 10_000,
              changed0: Optional[jnp.ndarray] = None, impl: str = "ref",
              kernel=None) -> Tuple[jnp.ndarray, RunStats]:
    spec = _resolve_kernel(kernel, impl)
    resilience.fire("engine.run", mode="async", impl=spec.impl,
                    fused=spec.fuse_frontier, batched=False)
    inv_n = jnp.float32(1.0 / max(p.n, 1))
    if changed0 is None:
        changed0 = jnp.ones(p.r_pad, dtype=bool)
    i, x, done, c = _async_loop(
        p.vals, p.cols, p.nnz, p.valid, p.dangling, p.group_tiles,
        p.group_edges, p.group_ext_tiles, p.row_edges, p.row_ext, x0,
        changed0, jnp.float32(damping), jnp.float32(tol), inv_n,
        p.semiring, apply_kind, max_sweeps, p.gb, p.s, spec)
    return x, _counter_stats(p, int(i), bool(done), c, "async")


# ---------------------------------------------------------------------------
# batched multi-source runners — vmap over the frontier-init axis
# ---------------------------------------------------------------------------
#
# One Prepared, one compile: the query axis (e.g. SSSP sources) is a vmap
# axis over x0, so Q queries share the device-resident BSR image and the
# traced program.  JAX's while_loop batching rule masks updates per query,
# so each query stops relaxing once it converges; reported sweeps is the
# straggler's (the batch retires together, like a wavefront of independent
# frontiers through the same NALE array).


def run_sync_batched(p: Prepared, x0: jnp.ndarray,
                     apply_kind: str = "relax", damping: float = 0.85,
                     tol: float = 1e-6, max_sweeps: int = 10_000,
                     impl: str = "ref", kernel=None,
                     changed0: Optional[jnp.ndarray] = None
                     ) -> Tuple[jnp.ndarray, RunStats]:
    """x0: (Q, r_pad, B) — returns ((Q, r_pad, B), aggregate RunStats)."""
    spec = _resolve_kernel(kernel, impl)
    resilience.fire("engine.run", mode="sync", impl=spec.impl,
                    fused=spec.fuse_frontier, batched=True)
    inv_n = jnp.float32(1.0 / max(p.n, 1))

    if spec.fuse_frontier:
        if changed0 is None:
            changed0 = jnp.ones((x0.shape[0], p.r_pad), dtype=bool)

        def one_fused(x0q, ch0q):
            return _sync_loop_fused(
                p.vals, p.cols, p.nnz, p.valid, p.row_edges, p.row_ext,
                x0q, ch0q, jnp.float32(damping), jnp.float32(tol), inv_n,
                p.semiring, apply_kind, max_sweeps, p.gb, p.s, spec)

        i, x, done, c = jax.vmap(one_fused)(x0, changed0)
        sweeps = np.asarray(i)
        return x, _counter_stats(p, int(sweeps.max(initial=0)),
                                 bool(np.all(done)), c, "sync")

    def one(x0q):
        return _sync_loop(p.vals, p.cols, p.nnz, p.valid, p.dangling, x0q,
                          jnp.float32(damping), jnp.float32(tol), inv_n,
                          p.semiring, apply_kind, max_sweeps, spec)

    i, x, done = jax.vmap(one)(x0)
    sweeps = np.asarray(i)
    return x, bsp_stats(p, int(sweeps.max(initial=0)), bool(np.all(done)),
                        "sync", work_sweeps=int(sweeps.sum()))


def run_async_batched(p: Prepared, x0: jnp.ndarray,
                      apply_kind: str = "relax", damping: float = 0.85,
                      tol: float = 1e-6, max_sweeps: int = 10_000,
                      changed0: Optional[jnp.ndarray] = None,
                      impl: str = "ref", kernel=None
                      ) -> Tuple[jnp.ndarray, RunStats]:
    """x0: (Q, r_pad, B); changed0: optional (Q, r_pad) per-query frontier."""
    spec = _resolve_kernel(kernel, impl)
    resilience.fire("engine.run", mode="async", impl=spec.impl,
                    fused=spec.fuse_frontier, batched=True)
    inv_n = jnp.float32(1.0 / max(p.n, 1))
    if changed0 is None:
        changed0 = jnp.ones((x0.shape[0], p.r_pad), dtype=bool)

    def one(x0q, ch0q):
        return _async_loop(
            p.vals, p.cols, p.nnz, p.valid, p.dangling, p.group_tiles,
            p.group_edges, p.group_ext_tiles, p.row_edges, p.row_ext,
            x0q, ch0q, jnp.float32(damping), jnp.float32(tol), inv_n,
            p.semiring, apply_kind, max_sweeps, p.gb, p.s, spec)

    i, x, done, c = jax.vmap(one)(x0, changed0)
    sweeps = np.asarray(i)
    return x, _counter_stats(p, int(sweeps.max(initial=0)),
                             bool(np.all(done)), c, "async")
