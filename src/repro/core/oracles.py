"""Reference (numpy) implementations of the paper's algorithms — test
oracles, and the "conventional CPU execution" semantics for the models."""

from __future__ import annotations

import heapq

import numpy as np

from .graph import Graph


def pagerank_oracle(g: Graph, damping: float = 0.85, tol: float = 1e-8,
                    max_iter: int = 500,
                    dangling: str = "drop") -> np.ndarray:
    """Power iteration.  dangling="drop" matches the engine semantics
    (no dangling-mass redistribution, final L1 renormalization)."""
    n = g.n
    outdeg = np.diff(g.indptr)
    inv = np.where(outdeg > 0, 1.0 / np.maximum(outdeg, 1), 0.0)
    x = np.full(n, 1.0 / n)
    src = np.repeat(np.arange(n), outdeg)
    for _ in range(max_iter):
        contrib = x[src] * inv[src]
        y = np.zeros(n)
        np.add.at(y, g.indices, contrib)
        dm = x[outdeg == 0].sum() if dangling == "redistribute" else 0.0
        x_new = (1 - damping) / n + damping * (y + dm / n)
        if np.max(np.abs(x_new - x)) <= tol:
            x = x_new
            break
        x = x_new
    if dangling == "drop":
        x = x / x.sum()
    return x


def sssp_oracle(g: Graph, src: int) -> np.ndarray:
    dist = np.full(g.n, np.inf)
    dist[src] = 0.0
    pq = [(0.0, src)]
    while pq:
        d, u = heapq.heappop(pq)
        if d > dist[u]:
            continue
        for e in range(g.indptr[u], g.indptr[u + 1]):
            v, w = g.indices[e], g.weights[e]
            nd = d + w
            if nd < dist[v]:
                dist[v] = nd
                heapq.heappush(pq, (nd, int(v)))
    return dist


def bfs_oracle(g: Graph, src: int) -> np.ndarray:
    level = np.full(g.n, np.inf)
    level[src] = 0
    frontier = [src]
    d = 0
    while frontier:
        nxt = []
        for u in frontier:
            for e in range(g.indptr[u], g.indptr[u + 1]):
                v = g.indices[e]
                if level[v] == np.inf:
                    level[v] = d + 1
                    nxt.append(int(v))
        frontier = nxt
        d += 1
    return level


def cc_oracle(g: Graph) -> np.ndarray:
    """Union-find component labels (canonical: min vertex id in component)."""
    parent = np.arange(g.n)

    def find(x):
        root = x
        while parent[root] != root:
            root = parent[root]
        while parent[x] != root:
            parent[x], x = root, parent[x]
        return root

    src = np.repeat(np.arange(g.n), np.diff(g.indptr))
    for u, v in zip(src, g.indices):
        ru, rv = find(u), find(int(v))
        if ru != rv:
            parent[max(ru, rv)] = min(ru, rv)
    return np.array([find(i) for i in range(g.n)])


def kcore_oracle(g: Graph, k: int) -> np.ndarray:
    """k-core membership (1.0 if the vertex survives peeling, else 0.0).

    Classic peeling on the undirected graph: repeatedly delete vertices
    with fewer than k live neighbours until a fixed point.
    """
    und = g.to_undirected()
    src = np.repeat(np.arange(und.n), np.diff(und.indptr))
    alive = np.ones(und.n, dtype=bool)
    while True:
        cnt = np.zeros(und.n, dtype=np.int64)
        live_edge = alive[src] & alive[und.indices]
        np.add.at(cnt, src[live_edge], 1)
        new = alive & (cnt >= k)
        if np.array_equal(new, alive):
            break
        alive = new
    return alive.astype(np.float32)


def tricount_oracle(g: Graph) -> np.ndarray:
    """Per-vertex triangle counts (dense adjacency; each triangle
    contributes 1 to each of its three corners)."""
    und = g.to_undirected()
    a = np.zeros((und.n, und.n), dtype=np.int64)
    src = np.repeat(np.arange(und.n), np.diff(und.indptr))
    a[src, und.indices] = 1
    a = np.maximum(a, a.T)
    np.fill_diagonal(a, 0)
    return ((a @ a) * a).sum(axis=1) // 2


def triangles_oracle(g: Graph) -> int:
    und = g.to_undirected()
    a = np.zeros((und.n, und.n), dtype=np.int64)
    src = np.repeat(np.arange(und.n), np.diff(und.indptr))
    a[src, und.indices] = 1
    return int(np.trace(a @ a @ a) // 6)


def dfs_oracle(g: Graph, src: int):
    """Iterative DFS visiting lowest-id neighbour first (matches engine)."""
    visited = np.zeros(g.n, dtype=bool)
    order, parent = [], np.full(g.n, -1)
    stack = [(src, -1)]
    while stack:
        u, pu = stack.pop()
        if visited[u]:
            continue
        visited[u] = True
        parent[u] = pu
        order.append(u)
        nbrs = sorted(g.indices[g.indptr[u]:g.indptr[u + 1]].tolist())
        for v in reversed(nbrs):
            if not visited[v]:
                stack.append((int(v), u))
    return np.array(order), parent
