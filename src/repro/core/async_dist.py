"""Self-timed asynchronous distributed engine — the paper's thesis at
the distributed level.

The bulk-synchronous engine (``placement.distributed_sync_run_batched``)
halo-exchanges every shard on every sweep: each sweep is paced by the
global worst case — exactly the global-clock execution the paper argues
against.  This module is the *self-timed* counterpart, one flavor knob
away (``ExecutionPolicy(mode="distributed", dist_flavor="async",
local_sweeps=k)``):

  * **k local sweeps per halo exchange.**  Each shard runs ``k``
    Gauss-Seidel-style relaxation sweeps between collectives: local
    reads are always fresh (a value produced by sweep ``s`` feeds sweep
    ``s+1`` immediately — the software analogue of values flowing
    through NALE FIFOs as soon as they are produced), remote reads come
    from the halo buffered at the start of the round.  For idempotent,
    monotone update rules (``semiring.UPDATE_RULES``: the semiring
    ``relax`` of SSSP/BFS/CC/reachability, k-core peeling, and
    GraphScale's ``pagerank_delta`` accumulation) a stale remote value
    is just a not-yet-improved bound, so the fixpoint is untouched while
    the collective count drops by up to ``k``.

  * **Self-timed shard pacing.**  A shard whose local sweep improved
    nothing idles for the rest of the round instead of re-relaxing an
    already-settled partition — each shard runs at its *local* rate, not
    the straggler's.  ``DistStats.shard_sweeps`` reports the per-shard
    active sweep counts that result.

  * **Overlapped, double-buffered halo exchange.**  The frontier
    all_gather is tiled along the "graph" axis (two buffers per round);
    the first sweep of a round relaxes *interior* clusters — rows whose
    in-tiles all live on this shard — from a purely local view that
    depends on neither tile, so XLA's latency-hiding scheduler is free
    to keep the boundary tiles in flight underneath the interior
    compute.  Boundary rows then combine the landed halo with the
    already-freshened interior values.

  * **Cheap convergence voting.**  The first sweep of every round is a
    complete relaxation pass against the round-start global state, so
    "no improvement anywhere" (one ``psum``-ed flag per query) is an
    exact global-fixpoint test: if interior relaxation improved nothing
    the local state is unchanged, hence a quiet boundary pass certifies
    the true bulk-synchronous convergence condition.  Per-query freezing
    matches the sync engine, so converged states are **bit-identical**
    to the bulk-synchronous path on every mesh factorization for the
    *exact* rules (min-plus path sums are associated tail-first in both
    engines; the fixpoint is a min over the same float multiset) and
    tolerance-bounded for accumulation rules like ``pagerank_delta``,
    whose float-add grouping legitimately differs across schedules.

PIUMA and GraphScale (PAPERS.md) center on the same compute /
communication overlap; here it is the difference between charging one
collective per sweep and one per ``k`` sweeps.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from . import semiring as sr
from .engine import Prepared, _apply
from .. import resilience
from .placement import (DistStats, ShardedBatch,  # noqa: F401 (re-export)
                        _shard_map, _spmv_ref, shard_batched_inputs)


def distributed_async_run_batched(
        p: Prepared, x0: jnp.ndarray, apply_kind: str = "relax",
        damping: float = 0.85, tol: float = 1e-6, max_sweeps: int = 10_000,
        mesh: Optional[Mesh] = None, query_axis: Optional[int] = None,
        local_sweeps: int = 2) -> Tuple[jnp.ndarray, DistStats]:
    """Batched self-timed distributed engine: ONE shard_map dispatch over
    the 2-D ``("graph", "query")`` mesh, ``local_sweeps`` relaxations per
    halo exchange.

    Same input layout and padding as the bulk-synchronous engine (both
    run on :func:`placement.shard_batched_inputs`); only the sweep /
    exchange schedule differs, so the converged state is bit-identical
    (exact rules) or tolerance-bounded (accumulation rules) while
    ``DistStats.halo_exchanges`` shrinks toward ``sweeps /
    local_sweeps``.

    Eligibility comes from the update-rule registry
    (``semiring.UPDATE_RULES``): the k-local-sweep schedule relies on
    the rule being idempotent and monotone (stale remote values are
    conservative bounds).  Classic PageRank's unconditional damped
    affine sweep is neither — use ``algo="pagerank_delta"`` (the
    GraphScale delta-accumulating form) or the bulk-synchronous flavor.
    """
    k = int(local_sweeps)
    if k < 1:
        raise ValueError(f"local_sweeps must be >= 1, got {local_sweeps}")
    if not sr.rule(apply_kind).monotone:
        eligible = sorted(n for n, r in sr.UPDATE_RULES.items()
                          if r.monotone)
        raise ValueError(
            "dist_flavor='async' requires an idempotent monotone update "
            f"rule ({', '.join(repr(e) for e in eligible)}); "
            f"apply_kind={apply_kind!r} is order-sensitive and needs the "
            "bulk-synchronous distributed engine (for PageRank, "
            "algo='pagerank_delta' is the flavor-eligible form)")
    sb = shard_batched_inputs(p, x0, mesh=mesh, query_axis=query_axis)
    Q, d_g, d_q = sb.q, sb.d_g, sb.d_q
    # host-level fault sites (after eligibility validation, so real API
    # misuse still surfaces as ValueError, never as an injected fault):
    # a straggling shard (delay) and a failed exchange round (raise)
    resilience.fire("dist.straggler", flavor="async", batched=True,
                    shards=d_g)
    resilience.fire("dist.dispatch", flavor="async", batched=True,
                    shards=d_g)
    rl = sb.r_pad // d_g            # local rows per "graph" shard
    ring = sr.get(p.semiring)
    inv_n = jnp.float32(1.0 / max(p.n, 1))
    damping = jnp.float32(damping)
    tol = jnp.float32(tol)
    max_rounds = -(-int(max_sweeps) // k)

    @functools.partial(
        _shard_map, mesh=sb.mesh,
        in_specs=(P("graph"), P("graph"), P("graph"), P("graph"),
                  P("query", "graph"), P("query")),
        out_specs=(P("query", "graph"), P("query"), P("query"), P(),
                   P("graph")),
        check_rep=False)
    def run(vals_l, cols_l, nnz_l, valid_l, x_l, qlive_l):
        row0 = jax.lax.axis_index("graph") * rl
        valid_b = valid_l[None]
        lane = jnp.arange(cols_l.shape[1])[None, :]
        live_tile = lane < nnz_l[:, None]
        local_col = (cols_l >= row0) & (cols_l < row0 + rl)
        # interior rows: every live in-tile reads this shard's rows —
        # relaxable before any halo byte lands
        interior = ~jnp.any(live_tile & ~local_col, axis=1)
        # local-coordinate column map for the interior (halo-free) view;
        # boundary rows read garbage through the clip and are masked out
        cols_rel = jnp.clip(cols_l - row0, 0, max(rl - 1, 0))

        spmv = jax.vmap(lambda cols, xq: _spmv_ref(
            vals_l, cols, nnz_l, xq, semiring=p.semiring),
            in_axes=(None, 0))

        def gather_halo(x):
            # tiled all_gather along "graph": two buffers per round so
            # boundary tiles stream while interior clusters relax
            tiles = [x] if rl < 2 else [x[:, : rl // 2], x[:, rl // 2:]]
            got = [jax.lax.all_gather(t, "graph", axis=0, tiled=False)
                   for t in tiles]
            h = got[0] if len(got) == 1 else jnp.concatenate(got, axis=2)
            return jnp.transpose(h, (1, 0, 2, 3)).reshape(
                x.shape[0], d_g * rl, x.shape[2])

        def overlay(halo, x):
            # buffered remote values + freshest local values
            return jax.lax.dynamic_update_slice(halo, x, (0, row0, 0))

        def relax(cols, xg, x):
            y = spmv(cols, xg)
            return _apply(apply_kind, ring, y, x, valid_b, damping,
                          inv_n, tol)

        def cond(st):
            i, x, done_q, lsw, sls, all_done = st
            return (~all_done) & (i < max_rounds)

        def body(st):
            i, x, done_q, lsw, sls, _ = st
            live = ~done_q
            # issue the round's halo exchange (boundary tiles in flight)
            halo = gather_halo(x)
            # sweep 0a — interior clusters, purely local view: no data
            # dependency on the gather above, so compute overlaps it
            x_i, imp_i = relax(cols_rel, x, x)
            upd_i = live[:, None, None] & interior[None, :, None]
            x = jnp.where(upd_i, x_i, x)
            # sweep 0b — boundary clusters: landed halo overlaid with
            # the freshly relaxed interior values (Gauss-Seidel order)
            x_b, imp_b = relax(cols_l, overlay(halo, x), x)
            upd_b = live[:, None, None] & ~interior[None, :, None]
            x = jnp.where(upd_b, x_b, x)
            imp0 = (imp_i & upd_i) | (imp_b & upd_b)
            imp0_q = jnp.any(imp0, axis=(1, 2))
            # sweep 0 is exact w.r.t. the round-start global state, so
            # this psum is the same convergence vote the BSP engine takes
            imp0_g = jax.lax.psum(
                imp0_q.astype(jnp.int32), "graph") > 0
            lsw = lsw + live.astype(jnp.int32)
            sls = sls + jnp.sum(live.astype(jnp.int32))
            # sweeps 1..k-1 — self-timed: each shard re-relaxes against
            # the buffered halo only while ITS local work keeps landing;
            # a settled shard idles until the next exchange
            active = live & imp0_g
            still = imp0_q
            for _ in range(k - 1):
                go = active & still
                x_n, imp = relax(cols_l, overlay(halo, x), x)
                x = jnp.where(go[:, None, None], x_n, x)
                still = jnp.any(imp, axis=(1, 2)) & go
                lsw = lsw + go.astype(jnp.int32)
                sls = sls + jnp.sum(go.astype(jnp.int32))
            done_q = done_q | ~imp0_g
            open_n = jax.lax.psum(jnp.sum(~done_q), "query")
            return i + 1, x, done_q, lsw, sls, open_n == 0

        done0 = ~qlive_l
        st = (jnp.int32(0), x_l, done0,
              jnp.zeros(x_l.shape[0], jnp.int32), jnp.int32(0),
              jnp.array(False))
        i, x, done_q, lsw, sls, _ = jax.lax.while_loop(cond, body, st)
        # per-query sweeps are the straggler shard's; per-shard totals
        # sum the query axis (both replicated along the reduced axis)
        return (x, jax.lax.pmax(lsw, "graph"), done_q, i[None],
                jax.lax.psum(sls, "query")[None])

    x, sweeps_q, done_q, exch, shard_sweeps = run(
        jnp.asarray(sb.vals), jnp.asarray(sb.cols), jnp.asarray(sb.nnz),
        jnp.asarray(sb.valid), jnp.asarray(sb.x0), jnp.asarray(sb.qlive))
    sweeps_q = np.asarray(sweeps_q)[:Q]
    stats = DistStats(
        sweeps=int(sweeps_q.max(initial=0)),
        converged=bool(np.all(np.asarray(done_q)[:Q])),
        halo_bytes_per_sweep=sb.halo_bytes_per_exchange(p.b),
        cut_fraction=p.clustering.cut_fraction,
        mesh_shape=(d_g, d_q), query_sweeps=sweeps_q,
        halo_exchanges=int(exch[0]), local_sweeps=k,
        shard_sweeps=np.asarray(shard_sweeps))
    return x[:Q, : p.r_pad], stats


def distributed_async_run(
        p: Prepared, x0: jnp.ndarray, apply_kind: str = "relax",
        damping: float = 0.85, tol: float = 1e-6, max_sweeps: int = 10_000,
        mesh: Optional[Mesh] = None,
        local_sweeps: int = 2) -> Tuple[jnp.ndarray, DistStats]:
    """Single-source self-timed distributed run: the batched engine with
    a query axis of one (``query_axis=1`` keeps the whole device grid on
    "graph", matching ``distributed_sync_run``'s 1-D layout)."""
    x, stats = distributed_async_run_batched(
        p, jnp.asarray(x0)[None], apply_kind=apply_kind, damping=damping,
        tol=tol, max_sweeps=max_sweeps, mesh=mesh, query_axis=1,
        local_sweeps=local_sweeps)
    return x[0], stats
