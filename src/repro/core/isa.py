"""The graph processor's specialized ISA (paper §II).

A NALE executes a small instruction set driven by FIFO readiness; the
co-processor compiles each cluster's work into a program of these ops.
We encode instructions as (opcode, a, b, c) int32 rows; ``compile.py``
generates per-cluster programs and ``power.py`` charges per-op costs.

Opcodes:
  GCFG  cfg_id, value, -      configure engine (semiring, apply rule, B)
  GLDX  col_block, -, -       load a source-value block into the FIFO/VMEM
  GMAC  tile_slot, col_block,- semiring MAC of one BxB tile against a block
  GCMP  row_block, -, -       three-state compare of new vs current values
  GAPP  row_block, rule, -    apply rule (relax / pagerank / identity)
  GSND  dst_cluster, nblocks,- send changed blocks downstream (handshake)
  GRCV  src_cluster, nblocks,- receive blocks (blocks until data ready)
  GSYN  -, -, -               local sweep boundary (no global barrier)
  GHLT  -, -, -               cluster converged
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np

OPCODES = {
    "GCFG": 0, "GLDX": 1, "GMAC": 2, "GCMP": 3, "GAPP": 4,
    "GSND": 5, "GRCV": 6, "GSYN": 7, "GHLT": 8,
}
MNEMONICS = {v: k for k, v in OPCODES.items()}

# per-instruction NALE cost model (cycles); GMAC's B is added dynamically
BASE_COST = {
    "GCFG": 1, "GLDX": 1, "GMAC": 0, "GCMP": 1, "GAPP": 1,
    "GSND": 2, "GRCV": 2, "GSYN": 1, "GHLT": 1,
}


def instr(op: str, a: int = 0, b: int = 0, c: int = 0) -> np.ndarray:
    return np.array([OPCODES[op], a, b, c], dtype=np.int32)


@dataclasses.dataclass
class Program:
    """One cluster's instruction stream."""

    cluster_id: int
    code: np.ndarray  # (m, 4) int32

    def __len__(self) -> int:
        return int(self.code.shape[0])

    def histogram(self) -> Dict[str, int]:
        h: Dict[str, int] = {k: 0 for k in OPCODES}
        ops, counts = np.unique(self.code[:, 0], return_counts=True)
        for o, c in zip(ops, counts):
            h[MNEMONICS[int(o)]] = int(c)
        return h

    def static_cycles(self, b: int) -> int:
        """Cycles for one full execution of the stream on a NALE with a
        B-lane MAC datapath (one tile row per cycle → GMAC costs B)."""
        h = self.histogram()
        cyc = sum(BASE_COST[k] * v for k, v in h.items())
        cyc += h["GMAC"] * b
        return cyc

    def disassemble(self, limit: int = 40) -> str:
        lines = []
        for i, (op, a, b, c) in enumerate(self.code[:limit]):
            lines.append(f"{i:4d}: {MNEMONICS[int(op)]:5s} {a:6d} {b:6d} {c:6d}")
        if len(self) > limit:
            lines.append(f"... ({len(self) - limit} more)")
        return "\n".join(lines)


def assemble(cluster_id: int, instrs: List[np.ndarray]) -> Program:
    code = np.stack(instrs) if instrs else np.zeros((0, 4), dtype=np.int32)
    return Program(cluster_id=cluster_id, code=code)
