"""Clustering, reordering, placement analysis — paper Fig. 4, steps 1–4.

The paper's compile flow: profile → extract topology → **cluster nodes** →
**cluster dependency analysis** → **placement** → compile.  Clustering is
what makes the architecture scale: a NALE executes either one node or a
whole node cluster, and load balance across NALEs comes from balanced
clusters with small cuts.

On TPU the same pass does double duty:
  * the cluster order is a vertex *permutation* that densifies edges into
    B×B tiles (BSR) so each tile is dense MXU/VPU work;
  * the cluster → device assignment is the graph-shard placement, and the
    inter-cluster dependency weights size the halo (ICI) traffic.

Everything here is one-time host-side preprocessing (numpy).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .graph import Graph


@dataclasses.dataclass
class Clustering:
    num_clusters: int
    assign: np.ndarray        # (n,) int32 — cluster id per (old) vertex
    perm: np.ndarray          # (n,) int32 — new id of old vertex v
    sizes: np.ndarray         # (num_clusters,) int32
    schedule: np.ndarray      # (num_clusters,) int32 — async sweep order
    internal_edges: int
    cut_edges: int

    @property
    def cut_fraction(self) -> float:
        total = self.internal_edges + self.cut_edges
        return self.cut_edges / max(total, 1)

    def balance(self) -> float:
        """max/mean cluster size — 1.0 is perfect."""
        return float(self.sizes.max() / max(self.sizes.mean(), 1e-9))


def _bfs_order(g: Graph, und: Optional[Graph] = None,
               seed: int = 0) -> np.ndarray:
    """BFS vertex order over the undirected graph (RCM-flavoured: restarts
    pick the lowest-degree unvisited vertex, which tends to start at graph
    peripheries and keep bandwidth low)."""
    und = und or g.to_undirected()
    n = g.n
    visited = np.zeros(n, dtype=bool)
    order = np.empty(n, dtype=np.int64)
    deg = und.out_degrees()
    pos = 0
    deg_order = np.argsort(deg, kind="stable")
    ptr = 0
    while pos < n:
        while ptr < n and visited[deg_order[ptr]]:
            ptr += 1
        if ptr >= n:
            rest = np.nonzero(~visited)[0]
            order[pos: pos + len(rest)] = rest
            break
        root = deg_order[ptr]
        # vectorized BFS frontier expansion
        frontier = np.array([root], dtype=np.int64)
        visited[root] = True
        order[pos] = root
        pos += 1
        while len(frontier):
            # gather all neighbours of the frontier (in frontier order —
            # RCM-style: children adopt their parent's position, which is
            # what keeps grid/planar graphs banded after relabeling)
            starts = und.indptr[frontier]
            ends = und.indptr[frontier + 1]
            counts = ends - starts
            if counts.sum() == 0:
                break
            idx = np.concatenate(
                [und.indices[s:e] for s, e in zip(starts, ends)])
            uniq, first_pos = np.unique(idx, return_index=True)
            live = ~visited[uniq]
            nxt = uniq[live][np.argsort(first_pos[live], kind="stable")]
            if len(nxt) == 0:
                break
            visited[nxt] = True
            order[pos: pos + len(nxt)] = nxt
            pos += len(nxt)
            frontier = nxt
    return order


def cluster_graph(g: Graph, num_clusters: int, seed: int = 0) -> Clustering:
    """Balanced BFS clustering + dependency-driven schedule.

    1. BFS-order vertices (locality: neighbours get nearby new ids).
    2. Chop the order into `num_clusters` equal contiguous chunks — balanced
       by construction (the paper's load-balancing requirement).
    3. Dependency analysis: weight W[c,d] = edges c→d; schedule clusters by
       BFS over the cluster DAG from high-out-degree roots, so a
       Gauss-Seidel sweep follows the direction information flows.
    """
    n = g.n
    num_clusters = max(1, min(num_clusters, n))
    order = _bfs_order(g, seed=seed)
    perm = np.empty(n, dtype=np.int64)
    perm[order] = np.arange(n)
    csize = (n + num_clusters - 1) // num_clusters
    assign = (perm // csize).astype(np.int32)
    sizes = np.bincount(assign, minlength=num_clusters).astype(np.int32)

    # cluster dependency matrix
    src = np.repeat(np.arange(n, dtype=np.int64), np.diff(g.indptr))
    cs, cd = assign[src], assign[g.indices]
    internal = int((cs == cd).sum())
    cut = int((cs != cd).sum())
    w = np.zeros((num_clusters, num_clusters), dtype=np.int64)
    np.add.at(w, (cs, cd), 1)
    np.fill_diagonal(w, 0)

    # schedule: BFS over cluster graph from the cluster holding vertex
    # new-id 0 (a BFS root), following dependency edges.
    sched = []
    seen = np.zeros(num_clusters, dtype=bool)
    frontier = [0]
    seen[0] = True
    while frontier:
        sched.extend(frontier)
        nxt_mask = (w[frontier].sum(axis=0) > 0) & ~seen
        nxt = list(np.nonzero(nxt_mask)[0])
        seen[nxt] = True
        frontier = nxt
    rest = list(np.nonzero(~seen)[0])
    sched.extend(rest)
    schedule = np.array(sched, dtype=np.int32)

    return Clustering(num_clusters=num_clusters, assign=assign,
                      perm=perm.astype(np.int64), sizes=sizes,
                      schedule=schedule, internal_edges=internal,
                      cut_edges=cut)


def identity_clustering(g: Graph, num_clusters: int) -> Clustering:
    """No-reorder baseline (what a naive mapping would do)."""
    n = g.n
    num_clusters = max(1, min(num_clusters, n))
    csize = (n + num_clusters - 1) // num_clusters
    assign = (np.arange(n) // csize).astype(np.int32)
    sizes = np.bincount(assign, minlength=num_clusters).astype(np.int32)
    src = np.repeat(np.arange(n, dtype=np.int64), np.diff(g.indptr))
    cs, cd = assign[src], assign[g.indices]
    return Clustering(num_clusters=num_clusters, assign=assign,
                      perm=np.arange(n, dtype=np.int64), sizes=sizes,
                      schedule=np.arange(num_clusters, dtype=np.int32),
                      internal_edges=int((cs == cd).sum()),
                      cut_edges=int((cs != cd).sum()))


def place_clusters(c: Clustering, num_devices: int) -> np.ndarray:
    """Placement (Fig. 4 step 4): clusters → devices, balancing vertex load
    greedily while keeping schedule-adjacent clusters together (adjacent
    clusters exchange the most halo traffic under BFS ordering)."""
    per = np.zeros(num_devices, dtype=np.int64)
    placement = np.zeros(c.num_clusters, dtype=np.int32)
    # contiguous chunks of the schedule, greedily balanced by size
    target = c.sizes.sum() / num_devices
    dev = 0
    for cid in c.schedule:
        if per[dev] >= target and dev < num_devices - 1:
            dev += 1
        placement[cid] = dev
        per[dev] += c.sizes[cid]
    return placement


def tile_stats_after(g: Graph, c: Clustering, b: int) -> dict:
    """How much does the clustering densify B×B tiles vs identity order?"""
    from .graph import to_bsr
    g2 = g.permute(c.perm.astype(np.int32))
    bsr0 = to_bsr(g, b)
    bsr1 = to_bsr(g2, b)
    return {
        "tiles_identity": bsr0.tiles,
        "tiles_clustered": bsr1.tiles,
        "fill_identity": bsr0.density_stats()["fill"],
        "fill_clustered": bsr1.density_stats()["fill"],
        "tile_reduction": bsr0.tiles / max(bsr1.tiles, 1),
    }
