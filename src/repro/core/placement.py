"""Cluster → device placement and the distributed graph engine.

Paper mapping: inter-NALE FIFOs become inter-device halo exchange.  Row
groups (clusters) are placed contiguously on a 1-D "graph" mesh axis by
``cluster.place_clusters``; each sweep a device gathers the frontier
values it needs (here: tiled all_gather — the collective the roofline
charges; the edge-cut from clustering bounds the useful fraction) and
computes its local rows.

Works on 1 real device (tests), on N fake host devices (subprocess tests,
dry-run) and unchanged on a real pod slice.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# jax.shard_map landed in 0.5.x; this container ships 0.4.x
_shard_map = getattr(jax, "shard_map", None)
if _shard_map is None:  # pragma: no cover - version dependent
    from jax.experimental.shard_map import shard_map as _shard_map

from . import semiring as sr
from .engine import Prepared, RunStats, _apply
from ..kernels import ref as kref


def make_graph_mesh(num_devices: Optional[int] = None) -> Mesh:
    n = num_devices or len(jax.devices())
    return jax.make_mesh((n,), ("graph",))


@dataclasses.dataclass
class DistStats:
    sweeps: int
    converged: bool
    halo_bytes_per_sweep: float   # all_gather payload (per device)
    cut_fraction: float


def _pad_rows(arr: np.ndarray, rows: int) -> np.ndarray:
    pad = rows - arr.shape[0]
    if pad <= 0:
        return arr
    widths = [(0, pad)] + [(0, 0)] * (arr.ndim - 1)
    return np.pad(arr, widths, constant_values=0)


def distributed_sync_run(
        p: Prepared, x0: jnp.ndarray, apply_kind: str = "relax",
        damping: float = 0.85, tol: float = 1e-6, max_sweeps: int = 10_000,
        mesh: Optional[Mesh] = None) -> Tuple[jnp.ndarray, DistStats]:
    """Bulk-synchronous distributed engine (shard_map over 'graph')."""
    mesh = mesh or make_graph_mesh()
    d = mesh.shape["graph"]
    ring = sr.get(p.semiring)

    r_pad = ((p.r_pad + d - 1) // d) * d
    vals = _pad_rows(np.asarray(p.vals), r_pad)
    cols = _pad_rows(np.asarray(p.cols), r_pad)
    nnz = _pad_rows(np.asarray(p.nnz), r_pad)
    valid = _pad_rows(np.asarray(p.valid), r_pad)
    x0 = _pad_rows(np.asarray(x0), r_pad).copy()
    if p.semiring in ("min_plus", "min_select"):
        # padding rows must not corrupt min-reductions
        x0[p.r_pad:] = np.inf
    inv_n = jnp.float32(1.0 / max(p.n, 1))
    damping = jnp.float32(damping)
    tol = jnp.float32(tol)

    @functools.partial(
        _shard_map, mesh=mesh,
        in_specs=(P("graph"), P("graph"), P("graph"), P("graph"),
                  P("graph")),
        out_specs=(P("graph"), P(), P()), check_rep=False)
    def run(vals_l, cols_l, nnz_l, valid_l, x_l):
        def cond(st):
            i, x_loc, done = st
            return (~done) & (i < max_sweeps)

        def body(st):
            i, x_loc, _ = st
            xg = jax.lax.all_gather(x_loc, "graph", tiled=True)
            y = kref.bsr_spmv_ref(vals_l, cols_l, xg, p.semiring)
            x_new, imp = _apply(apply_kind, ring, y, x_loc, valid_l,
                                damping, inv_n, tol)
            done = ~(jax.lax.psum(jnp.any(imp).astype(jnp.int32),
                                  "graph") > 0)
            return i + 1, x_new, done

        i, x_loc, done = jax.lax.while_loop(
            cond, body, (jnp.int32(0), x_l, False))
        return x_loc, i[None], done[None]

    x, i, done = run(jnp.asarray(vals), jnp.asarray(cols), jnp.asarray(nnz),
                     jnp.asarray(valid), jnp.asarray(x0))
    halo = (r_pad // d) * p.b * 4.0 * (d - 1)  # gathered remote bytes/device
    stats = DistStats(sweeps=int(i[0]), converged=bool(done[0]),
                      halo_bytes_per_sweep=float(halo),
                      cut_fraction=p.clustering.cut_fraction)
    return x[: p.r_pad], stats


def lower_distributed(p: Prepared, mesh: Mesh, apply_kind: str = "relax"):
    """Lower (no execution) the distributed sweep for dry-run inspection."""
    d = mesh.shape["graph"]
    r_pad = ((p.r_pad + d - 1) // d) * d
    ring = sr.get(p.semiring)
    shard = NamedSharding(mesh, P("graph"))

    def one_sweep(vals, cols, nnz, valid, x):
        @functools.partial(
            _shard_map, mesh=mesh,
            in_specs=(P("graph"),) * 5, out_specs=P("graph"),
            check_rep=False)
        def sweep(vals_l, cols_l, nnz_l, valid_l, x_l):
            xg = jax.lax.all_gather(x_l, "graph", tiled=True)
            y = kref.bsr_spmv_ref(vals_l, cols_l, xg, p.semiring)
            x_new, _ = _apply(apply_kind, ring, y, x_l, valid_l,
                              jnp.float32(0.85), jnp.float32(1.0 / p.n),
                              jnp.float32(1e-6))
            return x_new
        return sweep(vals, cols, nnz, valid, x)

    specs = [
        jax.ShapeDtypeStruct((r_pad, p.k_max, p.b, p.b), jnp.float32, sharding=shard),
        jax.ShapeDtypeStruct((r_pad, p.k_max), jnp.int32, sharding=shard),
        jax.ShapeDtypeStruct((r_pad,), jnp.int32, sharding=shard),
        jax.ShapeDtypeStruct((r_pad, p.b), jnp.bool_, sharding=shard),
        jax.ShapeDtypeStruct((r_pad, p.b), jnp.float32, sharding=shard),
    ]
    return jax.jit(one_sweep).lower(*specs)
