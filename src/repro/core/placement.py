"""Cluster → device placement and the distributed graph engine.

Paper mapping: inter-NALE FIFOs become inter-device halo exchange.  Row
groups (clusters) are placed contiguously on the "graph" axis of a 2-D
``("graph", "query")`` mesh by ``cluster.place_clusters``; each sweep a
device gathers the frontier values it needs (here: tiled all_gather —
the collective the roofline charges; the edge-cut from clustering bounds
the useful fraction) and computes its local rows.

The second mesh axis carries concurrent queries: the paper's
task-to-element mapping composes at both levels (PIUMA / GraphScale make
the same point), so multi-source frontiers shard over "query" while the
partitioned graph shards over "graph" — halo exchange stays confined to
"graph" because queries are independent.  ``query=1`` degenerates to the
historical 1-D behavior.

Works on 1 real device (tests), on N fake host devices (subprocess tests,
the CI multi-device lane, dry-run) and unchanged on a real pod slice.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# jax.shard_map landed in 0.5.x; this container ships 0.4.x
_shard_map = getattr(jax, "shard_map", None)
if _shard_map is None:  # pragma: no cover - version dependent
    from jax.experimental.shard_map import shard_map as _shard_map

from . import semiring as sr
from .engine import Prepared, _apply
from .. import resilience
from ..kernels import ops
from ..kernels.spec import KernelSpec

# the distributed engines shard_map the ref kernel (Pallas calls cannot
# be SPMD-partitioned); resolved once through the same registry the
# local engines use
_spmv_ref = ops.select_kernel("bsr_spmv", KernelSpec(impl="ref"))


def make_graph_mesh(num_devices: Optional[int] = None,
                    query_axis: int = 1) -> Mesh:
    """2-D ``("graph", "query")`` device mesh.

    ``num_devices`` (default: all) are factored as
    ``graph = num_devices // query_axis``; ``query_axis=1`` is the
    degenerate 1-D layout every pre-existing caller gets.
    """
    n = num_devices or len(jax.devices())
    q = int(query_axis)
    if q < 1:
        raise ValueError(f"query_axis must be >= 1, got {q}")
    if n % q:
        raise ValueError(
            f"query_axis={q} does not divide {n} devices; pick a "
            f"divisor of the device count (see factor_query_axis)")
    return jax.make_mesh((n // q, q), ("graph", "query"))


def factor_query_axis(num_devices: int, num_queries: int) -> int:
    """Auto-factor the device count for a Q-source batch: the largest
    divisor of ``num_devices`` not exceeding ``num_queries``, so both
    mesh axes stay as full as the batch allows (q queries can't feed
    more than q query-shards; leftover devices go to "graph")."""
    q = max(int(num_queries), 1)
    for cand in range(min(q, num_devices), 0, -1):
        if num_devices % cand == 0:
            return cand
    return 1


@dataclasses.dataclass
class DistStats:
    sweeps: int
    converged: bool
    halo_bytes_per_sweep: float   # all_gather payload per exchange (per device)
    cut_fraction: float
    mesh_shape: Tuple[int, int] = (1, 1)       # (graph, query) extent
    query_sweeps: Optional[np.ndarray] = None  # per-query sweep counts
    # self-timed accounting (PR 7): the bulk-synchronous engines exchange
    # once per sweep, so halo_exchanges == sweeps there; the async flavor
    # (core/async_dist.py) runs local_sweeps relaxations per exchange and
    # reports strictly fewer exchanges on multi-sweep fixpoints.
    halo_exchanges: int = 0
    local_sweeps: int = 1                      # k (1 = bulk-synchronous)
    shard_sweeps: Optional[np.ndarray] = None  # per-"graph"-shard active
    #                                            local sweeps (self-timed
    #                                            rate of each shard)


def _pad_rows(arr: np.ndarray, rows: int) -> np.ndarray:
    pad = rows - arr.shape[0]
    if pad <= 0:
        return arr
    widths = [(0, pad)] + [(0, 0)] * (arr.ndim - 1)
    return np.pad(arr, widths, constant_values=0)


@dataclasses.dataclass
class ShardedBatch:
    """Host-side scaffolding shared by every batched distributed flavor:
    the mesh, the row/query padding, and the padded input arrays a
    ``("graph", "query")`` shard_map dispatch consumes.

    Built by :func:`shard_batched_inputs`; both the bulk-synchronous
    engine (:func:`distributed_sync_run_batched`) and the self-timed
    asynchronous one (``core.async_dist``) run on exactly this layout,
    which is what makes their converged states comparable bit-for-bit.
    """

    mesh: Mesh
    d_g: int                # "graph" extent
    d_q: int                # "query" extent
    r_pad: int              # rows padded to a multiple of d_g
    q_pad: int              # queries padded to a multiple of d_q
    q: int                  # real (un-padded) query count
    vals: np.ndarray
    cols: np.ndarray
    nnz: np.ndarray
    valid: np.ndarray
    x0: np.ndarray          # (q_pad, r_pad, B)
    qlive: np.ndarray       # (q_pad,) — padding queries start converged

    def halo_bytes_per_exchange(self, b: int) -> float:
        """Remote bytes a device gathers in ONE tiled all_gather of the
        frontier (summed over its resident query rows)."""
        return (self.r_pad // self.d_g) * b * 4.0 * (self.d_g - 1) * \
            (self.q_pad // self.d_q)


def shard_batched_inputs(p: Prepared, x0: jnp.ndarray,
                         mesh: Optional[Mesh] = None,
                         query_axis: Optional[int] = None) -> ShardedBatch:
    """Pad a ``Prepared`` image and a stacked ``(Q, r_pad, B)`` frontier
    for a 2-D ``("graph", "query")`` mesh dispatch.

    Rows are padded to a multiple of the "graph" extent (min-semiring
    padding rows hold +inf so they never win a reduction), queries to a
    multiple of the "query" extent (padding queries are marked dead in
    ``qlive`` — converged from sweep 0, zero work).  ``query_axis=None``
    auto-factors the device count against the batch size; 0 is rejected
    here for every flavor (the per-source escape hatch lives in the
    session API, not the engines).
    """
    Q = int(x0.shape[0])
    if query_axis is not None and query_axis < 1:
        # the query_axis=0 per-source escape hatch lives one layer up
        # (GraphProcessor._run_batched) — the engine itself must never
        # silently reinterpret 0 as "auto-factor"
        raise ValueError(
            "batched distributed engines need query_axis=None (auto) "
            f"or >= 1, got {query_axis}; the query_axis=0 per-source "
            "loop is dispatched by the session API, not the engine")
    if mesh is None:
        ndev = len(jax.devices())
        mesh = make_graph_mesh(
            ndev, query_axis or factor_query_axis(ndev, Q))
    shape = dict(mesh.shape)
    d_g = shape["graph"]
    d_q = shape.get("query", 1)

    r_pad = ((p.r_pad + d_g - 1) // d_g) * d_g
    vals = _pad_rows(np.asarray(p.vals), r_pad)
    cols = _pad_rows(np.asarray(p.cols), r_pad)
    nnz = _pad_rows(np.asarray(p.nnz), r_pad)
    valid = _pad_rows(np.asarray(p.valid), r_pad)
    q_pad = ((Q + d_q - 1) // d_q) * d_q
    x0 = np.asarray(x0)
    x0 = np.concatenate(
        [x0, np.zeros((q_pad - Q,) + x0.shape[1:], x0.dtype)])
    x0 = np.stack([_pad_rows(x0[qi], r_pad) for qi in range(q_pad)])
    # padding rows hold the ⊕-identity so they never win a reduction
    # (inf for the min semirings, 0 for plus_times/max_min — the value
    # np.pad already wrote, so this is a no-op there)
    x0[:, p.r_pad:] = sr.get(p.semiring).zero
    # padding queries start converged: frozen from sweep 0, zero work
    qlive = np.arange(q_pad) < Q
    return ShardedBatch(mesh=mesh, d_g=d_g, d_q=d_q, r_pad=r_pad,
                        q_pad=q_pad, q=Q, vals=vals, cols=cols, nnz=nnz,
                        valid=valid, x0=x0, qlive=qlive)


def distributed_sync_run(
        p: Prepared, x0: jnp.ndarray, apply_kind: str = "relax",
        damping: float = 0.85, tol: float = 1e-6, max_sweeps: int = 10_000,
        mesh: Optional[Mesh] = None) -> Tuple[jnp.ndarray, DistStats]:
    """Bulk-synchronous distributed engine (shard_map over 'graph')."""
    mesh = mesh or make_graph_mesh()
    d = mesh.shape["graph"]
    # host-level fault sites: an exchange-round failure (raise) and a
    # straggling shard (delay) — shard_map bodies are compiled, so the
    # engine's dispatch boundary is where injection can model them
    resilience.fire("dist.straggler", flavor="sync", batched=False,
                    shards=d)
    resilience.fire("dist.dispatch", flavor="sync", batched=False,
                    shards=d)
    ring = sr.get(p.semiring)

    r_pad = ((p.r_pad + d - 1) // d) * d
    vals = _pad_rows(np.asarray(p.vals), r_pad)
    cols = _pad_rows(np.asarray(p.cols), r_pad)
    nnz = _pad_rows(np.asarray(p.nnz), r_pad)
    valid = _pad_rows(np.asarray(p.valid), r_pad)
    x0 = _pad_rows(np.asarray(x0), r_pad).copy()
    # padding rows hold the ⊕-identity so they never win a reduction
    x0[p.r_pad:] = ring.zero
    inv_n = jnp.float32(1.0 / max(p.n, 1))
    damping = jnp.float32(damping)
    tol = jnp.float32(tol)

    @functools.partial(
        _shard_map, mesh=mesh,
        in_specs=(P("graph"), P("graph"), P("graph"), P("graph"),
                  P("graph")),
        out_specs=(P("graph"), P(), P()), check_rep=False)
    def run(vals_l, cols_l, nnz_l, valid_l, x_l):
        def cond(st):
            i, x_loc, done = st
            return (~done) & (i < max_sweeps)

        def body(st):
            i, x_loc, _ = st
            xg = jax.lax.all_gather(x_loc, "graph", tiled=True)
            y = _spmv_ref(vals_l, cols_l, nnz_l, xg, semiring=p.semiring)
            x_new, imp = _apply(apply_kind, ring, y, x_loc, valid_l,
                                damping, inv_n, tol)
            done = ~(jax.lax.psum(jnp.any(imp).astype(jnp.int32),
                                  "graph") > 0)
            return i + 1, x_new, done

        i, x_loc, done = jax.lax.while_loop(
            cond, body, (jnp.int32(0), x_l, False))
        return x_loc, i[None], done[None]

    x, i, done = run(jnp.asarray(vals), jnp.asarray(cols), jnp.asarray(nnz),
                     jnp.asarray(valid), jnp.asarray(x0))
    halo = (r_pad // d) * p.b * 4.0 * (d - 1)  # gathered remote bytes/device
    stats = DistStats(sweeps=int(i[0]), converged=bool(done[0]),
                      halo_bytes_per_sweep=float(halo),
                      cut_fraction=p.clustering.cut_fraction,
                      mesh_shape=(d, dict(mesh.shape).get("query", 1)),
                      halo_exchanges=int(i[0]))  # BSP: one per sweep
    return x[: p.r_pad], stats


def distributed_sync_run_batched(
        p: Prepared, x0: jnp.ndarray, apply_kind: str = "relax",
        damping: float = 0.85, tol: float = 1e-6, max_sweeps: int = 10_000,
        mesh: Optional[Mesh] = None, query_axis: Optional[int] = None
        ) -> Tuple[jnp.ndarray, DistStats]:
    """Batched distributed engine: ONE shard_map dispatch over the 2-D
    ``("graph", "query")`` mesh for a stacked ``(Q, r_pad, B)`` frontier.

    Rows shard over "graph" exactly as in :func:`distributed_sync_run`;
    the query axis shards over "query".  Halo exchange (the tiled
    all_gather of frontier values) runs only along "graph" — queries are
    independent, so no bytes cross the "query" axis except the scalar
    convergence vote.  Each query freezes (bit-exactly, including its
    final no-improvement sweep — the same last write the sequential loop
    does) once it individually converges, so results are bit-identical
    to running the sources one at a time through the sequential
    distributed engine, for any mesh factorization.

    ``query_axis``: explicit "query" extent (must divide the device
    count); None auto-factors via :func:`factor_query_axis`.  Ignored
    when ``mesh`` is given.
    """
    sb = shard_batched_inputs(p, x0, mesh=mesh, query_axis=query_axis)
    Q, d_g, d_q = sb.q, sb.d_g, sb.d_q
    resilience.fire("dist.straggler", flavor="sync", batched=True,
                    shards=d_g)
    resilience.fire("dist.dispatch", flavor="sync", batched=True,
                    shards=d_g)
    ring = sr.get(p.semiring)
    inv_n = jnp.float32(1.0 / max(p.n, 1))
    damping = jnp.float32(damping)
    tol = jnp.float32(tol)

    @functools.partial(
        _shard_map, mesh=sb.mesh,
        in_specs=(P("graph"), P("graph"), P("graph"), P("graph"),
                  P("query", "graph"), P("query")),
        out_specs=(P("query", "graph"), P("query"), P("query")),
        check_rep=False)
    def run(vals_l, cols_l, nnz_l, valid_l, x_l, qlive_l):
        spmv = jax.vmap(lambda xq: _spmv_ref(vals_l, cols_l, nnz_l, xq,
                                             semiring=p.semiring))

        def cond(st):
            i, x, done_q, sweeps_q, all_done = st
            return (~all_done) & (i < max_sweeps)

        def body(st):
            i, x, done_q, sweeps_q, _ = st
            # halo exchange: ONLY along "graph" — queries are independent
            xg = jax.lax.all_gather(x, "graph", axis=1, tiled=True)
            y = spmv(xg)
            x_new, imp = _apply(apply_kind, ring, y, x, valid_l[None],
                                damping, inv_n, tol)
            live = ~done_q
            # a live query's final (no-improvement) sweep still writes
            # x_new and counts — exactly like the sequential while_loop
            x = jnp.where(live[:, None, None], x_new, x)
            sweeps_q = sweeps_q + live.astype(jnp.int32)
            imp_q = jax.lax.psum(
                jnp.any(imp, axis=(1, 2)).astype(jnp.int32), "graph") > 0
            done_q = done_q | ~imp_q
            # scalar convergence vote — the only cross-"query" traffic
            open_n = jax.lax.psum(jnp.sum(~done_q), "query")
            return i + 1, x, done_q, sweeps_q, open_n == 0

        done0 = ~qlive_l
        st = (jnp.int32(0), x_l, done0,
              jnp.zeros(x_l.shape[0], jnp.int32), jnp.array(False))
        _, x, done_q, sweeps_q, _ = jax.lax.while_loop(cond, body, st)
        return x, sweeps_q, done_q

    x, sweeps_q, done_q = run(
        jnp.asarray(sb.vals), jnp.asarray(sb.cols), jnp.asarray(sb.nnz),
        jnp.asarray(sb.valid), jnp.asarray(sb.x0), jnp.asarray(sb.qlive))
    sweeps_q = np.asarray(sweeps_q)[:Q]
    straggler = int(sweeps_q.max(initial=0))
    stats = DistStats(
        sweeps=straggler,
        converged=bool(np.all(np.asarray(done_q)[:Q])),
        halo_bytes_per_sweep=sb.halo_bytes_per_exchange(p.b),
        cut_fraction=p.clustering.cut_fraction,
        mesh_shape=(d_g, d_q), query_sweeps=sweeps_q,
        halo_exchanges=straggler)  # bulk-synchronous: one per sweep
    return x[:Q, : p.r_pad], stats


def lower_distributed(p: Prepared, mesh: Mesh, apply_kind: str = "relax",
                      batch: Optional[int] = None):
    """Lower (no execution) the distributed sweep for dry-run inspection.

    ``batch=Q`` lowers the 2-D batched sweep instead: a ``(Q, r_pad, B)``
    frontier sharded ``P("query", "graph")`` — the collective layout CI
    and dry-run tooling inspect to confirm the halo exchange stays on
    "graph"."""
    shape = dict(mesh.shape)
    d = shape["graph"]
    d_q = shape.get("query", 1)
    r_pad = ((p.r_pad + d - 1) // d) * d
    ring = sr.get(p.semiring)
    shard = NamedSharding(mesh, P("graph"))

    def one_sweep(vals, cols, nnz, valid, x):
        @functools.partial(
            _shard_map, mesh=mesh,
            in_specs=(P("graph"),) * 4 + (
                P("query", "graph") if batch else P("graph"),),
            out_specs=P("query", "graph") if batch else P("graph"),
            check_rep=False)
        def sweep(vals_l, cols_l, nnz_l, valid_l, x_l):
            if batch:
                xg = jax.lax.all_gather(x_l, "graph", axis=1, tiled=True)
                y = jax.vmap(lambda xq: _spmv_ref(
                    vals_l, cols_l, nnz_l, xq, semiring=p.semiring))(xg)
                valid_b = valid_l[None]
            else:
                xg = jax.lax.all_gather(x_l, "graph", tiled=True)
                y = _spmv_ref(vals_l, cols_l, nnz_l, xg,
                              semiring=p.semiring)
                valid_b = valid_l
            x_new, _ = _apply(apply_kind, ring, y, x_l, valid_b,
                              jnp.float32(0.85), jnp.float32(1.0 / p.n),
                              jnp.float32(1e-6))
            return x_new
        return sweep(vals, cols, nnz, valid, x)

    specs = [
        jax.ShapeDtypeStruct((r_pad, p.k_max, p.b, p.b), jnp.float32, sharding=shard),
        jax.ShapeDtypeStruct((r_pad, p.k_max), jnp.int32, sharding=shard),
        jax.ShapeDtypeStruct((r_pad,), jnp.int32, sharding=shard),
        jax.ShapeDtypeStruct((r_pad, p.b), jnp.bool_, sharding=shard),
    ]
    if batch:
        q_pad = ((int(batch) + d_q - 1) // d_q) * d_q
        specs.append(jax.ShapeDtypeStruct(
            (q_pad, r_pad, p.b), jnp.float32,
            sharding=NamedSharding(mesh, P("query", "graph"))))
    else:
        specs.append(jax.ShapeDtypeStruct(
            (r_pad, p.b), jnp.float32, sharding=shard))
    return jax.jit(one_sweep).lower(*specs)
