"""RWKV-6 "Finch" block — attention-free linear RNN with data-dependent
decay (token-shift ddlerp projections, per-channel decay from a low-rank
MLP, multi-head matrix-valued state).

Paper tie-in: the WKV recurrence is a pure dataflow — each step's work
depends only on its inputs' readiness, the property the paper's
self-timed NALEs exploit.  We express it as lax.scan (sequential
dependency chain made explicit to XLA); decode is a single state update.

State per layer: (B, H, hs, hs) wkv state + (B, D) token-shift states for
the time-mix and channel-mix halves.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import layers


def rwkv_init(cfg: ModelConfig, key):
    d = cfg.d_model
    hs = cfg.rwkv_head_size
    h = d // hs
    r = cfg.ddlerp_rank
    dr = cfg.decay_rank
    dt = layers.dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 16)
    p = {
        # token-shift ddlerp: mu_x + low-rank data-dependent interpolation
        "mu": 0.5 * jnp.ones((5, d), jnp.float32),        # r,k,v,w,g
        "ddl_a": layers._init(ks[0], (d, 5 * r), d, dt),
        "ddl_b": layers._init(ks[1], (5, r, d), r, dt),
        # projections
        "wr": layers._init(ks[2], (d, d), d, dt),
        "wk": layers._init(ks[3], (d, d), d, dt),
        "wv": layers._init(ks[4], (d, d), d, dt),
        "wg": layers._init(ks[5], (d, d), d, dt),
        "wo": layers._init(ks[6], (d, d), d, dt),
        # data-dependent decay (low-rank) + per-channel boost u
        "w0": -6.0 * jnp.ones((d,), jnp.float32),
        "dec_a": layers._init(ks[7], (d, dr), d, dt),
        "dec_b": layers._init(ks[8], (dr, d), dr, dt),
        "u": jnp.zeros((h, hs), jnp.float32),
        "ln_x": jnp.ones((d,), jnp.float32),              # per-head norm
        # channel mix
        "mu_c": 0.5 * jnp.ones((2, d), jnp.float32),
        "ck": layers._init(ks[9], (d, cfg.d_ff), d, dt),
        "cr": layers._init(ks[10], (d, d), d, dt),
        "cv": layers._init(ks[11], (cfg.d_ff, d), cfg.d_ff, dt),
    }
    a = {
        "mu": ". embed", "ddl_a": "embed lora", "ddl_b": ". lora embed",
        "wr": "embed mlp", "wk": "embed mlp", "wv": "embed mlp",
        "wg": "embed mlp", "wo": "mlp embed",
        "w0": "norm", "dec_a": "embed lora", "dec_b": "lora embed",
        "u": "heads head_dim", "ln_x": "norm",
        "mu_c": ". embed", "ck": "embed mlp", "cr": "embed mlp",
        "cv": "mlp embed",
    }
    return p, a


def _ddlerp(p, x, x_prev, cd):
    """RWKV6 data-dependent token-shift: 5 interpolated views of (x, x-1)."""
    dx = x_prev - x                                       # (B,S,D)
    base = x + dx * p["mu"].astype(cd)[:, None, None, :]  # (5,B,S,D)
    lora = jnp.tanh(dx @ p["ddl_a"].astype(cd))           # (B,S,5r)
    b, s, _ = x.shape
    r = p["ddl_b"].shape[1]
    lora = lora.reshape(b, s, 5, r).transpose(2, 0, 1, 3)  # (5,B,S,r)
    adj = jnp.einsum("nbsr,nrd->nbsd", lora, p["ddl_b"].astype(cd))
    return base + adj * dx[None]


TIME_CHUNK = 512


def _wkv_scan(r, k, v, w, u, state):
    """Multi-head WKV recurrence.
    r,k,v: (B,S,H,hs); w: (B,S,H,hs) decay in (0,1); u: (H,hs).
    state: (B,H,hs,hs) keyed [k_dim, v_dim].  Returns (y, state').

    Long sequences scan over TIME_CHUNK-step chunks with remat inside each
    chunk, so the backward pass saves one state per chunk instead of one
    per step (34 GB → 134 MB at train_4k/1.6B scale, DESIGN.md §8)."""

    def step(s_, inp):
        r_t, k_t, v_t, w_t = inp                      # (B,H,hs)
        a_t = jnp.einsum("bhk,bhv->bhkv", k_t, v_t)   # outer product
        y_t = jnp.einsum("bhk,bhkv->bhv", r_t,
                         s_ + u[None, :, :, None] * a_t)
        s_ = w_t[..., None] * s_ + a_t
        return s_, y_t

    def chunk(s_, inp):
        return jax.lax.scan(step, s_, inp)

    s = r.shape[1]
    xs = (r.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
          v.transpose(1, 0, 2, 3), w.transpose(1, 0, 2, 3))
    if s % TIME_CHUNK == 0 and s > TIME_CHUNK:
        nc = s // TIME_CHUNK
        xs_c = jax.tree.map(
            lambda t: t.reshape((nc, TIME_CHUNK) + t.shape[1:]), xs)
        state, ys = jax.lax.scan(
            jax.checkpoint(chunk,
                           policy=jax.checkpoint_policies.nothing_saveable),
            state, xs_c)
        ys = ys.reshape((s,) + ys.shape[2:])
    else:
        state, ys = jax.lax.scan(step, state, xs)
    return ys.transpose(1, 0, 2, 3), state            # (B,S,H,hs)


def rwkv_time_mix(cfg: ModelConfig, p, x, state, x_prev_last):
    """x: (B,S,D); state: (B,H,hs,hs); x_prev_last: (B,D) = last token of
    the previous chunk (token shift across chunk/step boundaries)."""
    cd = layers.dtype_of(cfg.compute_dtype)
    b, s, d = x.shape
    hs = cfg.rwkv_head_size
    h = d // hs
    x_prev = jnp.concatenate(
        [x_prev_last[:, None, :].astype(x.dtype), x[:, :-1]], axis=1)
    xr, xk, xv, xw, xg = _ddlerp(p, x, x_prev, cd)
    r = (xr @ p["wr"].astype(cd)).reshape(b, s, h, hs)
    k = (xk @ p["wk"].astype(cd)).reshape(b, s, h, hs)
    v = (xv @ p["wv"].astype(cd)).reshape(b, s, h, hs)
    g = jax.nn.silu(xg @ p["wg"].astype(cd))
    dec = p["w0"] + jnp.tanh(xw @ p["dec_a"].astype(cd)).astype(jnp.float32) \
        @ p["dec_b"].astype(jnp.float32)
    w = jnp.exp(-jnp.exp(dec)).astype(cd).reshape(b, s, h, hs)
    u = p["u"].astype(cd)
    y, state = _wkv_scan(r, k, v, w, u, state.astype(cd))
    # per-head group norm
    yf = y.astype(jnp.float32)
    mu = yf.mean(-1, keepdims=True)
    var = yf.var(-1, keepdims=True)
    yn = ((yf - mu) * jax.lax.rsqrt(var + 1e-5)).reshape(b, s, d) \
        * p["ln_x"]
    out = (yn.astype(cd) * g) @ p["wo"].astype(cd)
    return out, state, x[:, -1, :]


def rwkv_channel_mix(cfg: ModelConfig, p, x, x_prev_last):
    cd = layers.dtype_of(cfg.compute_dtype)
    x_prev = jnp.concatenate(
        [x_prev_last[:, None, :].astype(x.dtype), x[:, :-1]], axis=1)
    dx = x_prev - x
    mu = p["mu_c"].astype(cd)
    xk = x + dx * mu[0]
    xr = x + dx * mu[1]
    kk = jnp.square(jax.nn.relu(xk @ p["ck"].astype(cd)))
    rr = jax.nn.sigmoid(xr @ p["cr"].astype(cd))
    return rr * (kk @ p["cv"].astype(cd)), x[:, -1, :]


def rwkv_block_apply(cfg: ModelConfig, p, x, state) -> Tuple:
    """Full block (time-mix + channel-mix), chunk mode (train/prefill).

    state dict: {"wkv": (B,H,hs,hs), "tm_x": (B,D), "cm_x": (B,D)}.
    Caller handles the pre-norms/residuals.
    """
    tm_out, wkv, tm_x = rwkv_time_mix(cfg, p, x, state["wkv"],
                                      state["tm_x"])
    return tm_out, {"wkv": wkv, "tm_x": tm_x, "cm_x": state["cm_x"]}


def rwkv_state_init(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    d = cfg.d_model
    hs = cfg.rwkv_head_size
    h = d // hs
    return {"wkv": jnp.zeros((batch, h, hs, hs), dtype),
            "tm_x": jnp.zeros((batch, d), dtype),
            "cm_x": jnp.zeros((batch, d), dtype)}


def rwkv_state_axes():
    return {"wkv": "batch heads head_dim head_dim",
            "tm_x": "batch .", "cm_x": "batch ."}
