"""Griffin / RecurrentGemma recurrent block: gated linear branch ×
(conv1d → RG-LRU) branch.

RG-LRU: r_t = σ(Wr x_t), i_t = σ(Wi x_t), a_t = a^(c·r_t) with
a = σ(Λ) learnable, c = 8;  h_t = a_t ⊙ h_{t-1} + √(1−a_t²) ⊙ (i_t ⊙ x_t).

State per recurrent layer: h (B, lru_dim) + conv tap history
(B, conv_width−1, lru_dim).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import layers

_C = 8.0
_TIME_CHUNK = 512  # remat chunk for the LRU scan (see rwkv.TIME_CHUNK)


def recurrent_init(cfg: ModelConfig, key):
    d, ld = cfg.d_model, cfg.lru_dim
    dt = layers.dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    p = {
        "w_x": layers._init(ks[0], (d, ld), d, dt),      # recurrent branch
        "w_y": layers._init(ks[1], (d, ld), d, dt),      # gate branch
        "conv_w": layers._init(ks[2], (cfg.conv_width, ld), cfg.conv_width, dt),
        "conv_b": jnp.zeros((ld,), jnp.float32),
        "wr": layers._init(ks[3], (ld, ld), ld, dt),
        "wi": layers._init(ks[4], (ld, ld), ld, dt),
        "lam": jnp.log(jnp.expm1(jnp.full((ld,), 4.0))),  # a ≈ σ(Λ) ≈ .98
        "w_out": layers._init(ks[5], (ld, d), ld, dt),
    }
    a = {"w_x": "embed mlp", "w_y": "embed mlp", "conv_w": "conv mlp",
         "conv_b": "norm", "wr": "mlp mlp2", "wi": "mlp mlp2",
         "lam": "norm", "w_out": "mlp embed"}
    return p, a


def _rg_lru(p, x, h0, cd):
    """x: (B,S,ld) post-conv; h0: (B,ld).  Returns (y, h_last)."""
    r = jax.nn.sigmoid(jnp.einsum("bsl,lm->bsm", x, p["wr"].astype(cd))
                       .astype(jnp.float32))
    i = jax.nn.sigmoid(jnp.einsum("bsl,lm->bsm", x, p["wi"].astype(cd))
                       .astype(jnp.float32))
    log_a_base = -jax.nn.softplus(-p["lam"])          # log σ(Λ)
    log_a = _C * r * log_a_base[None, None, :]        # (B,S,ld)
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9))
    gated = (i * x.astype(jnp.float32)) * beta

    def step(h, inp):
        a_t, g_t = inp
        h = a_t * h + g_t
        return h, h

    def chunk(h, inp):
        return jax.lax.scan(step, h, inp)

    s = x.shape[1]
    xs = (a.transpose(1, 0, 2), gated.transpose(1, 0, 2))
    if s % _TIME_CHUNK == 0 and s > _TIME_CHUNK:
        nc = s // _TIME_CHUNK
        xs_c = jax.tree.map(
            lambda t: t.reshape((nc, _TIME_CHUNK) + t.shape[1:]), xs)
        h_last, ys = jax.lax.scan(
            jax.checkpoint(chunk,
                           policy=jax.checkpoint_policies.nothing_saveable),
            h0.astype(jnp.float32), xs_c)
        ys = ys.reshape((s,) + ys.shape[2:])
    else:
        h_last, ys = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    return ys.transpose(1, 0, 2).astype(cd), h_last


def _causal_conv(p, x, taps, cd):
    """width-W depthwise causal conv.  taps: (B, W-1, ld) history."""
    w = p["conv_w"].astype(cd)                        # (W, ld)
    full = jnp.concatenate([taps.astype(cd), x], axis=1)
    width = w.shape[0]
    s = x.shape[1]
    out = sum(full[:, i: i + s, :] * w[width - 1 - i]
              for i in range(width))
    return out + p["conv_b"].astype(cd), full[:, -(width - 1):, :]


def recurrent_apply(cfg: ModelConfig, p, x, state):
    """x: (B,S,D); state {"h": (B,ld), "conv": (B,W-1,ld)}."""
    cd = layers.dtype_of(cfg.compute_dtype)
    xr = jnp.einsum("bsd,dl->bsl", x, p["w_x"].astype(cd))
    gate = jax.nn.gelu(jnp.einsum("bsd,dl->bsl", x, p["w_y"].astype(cd)))
    xc, conv_taps = _causal_conv(p, xr, state["conv"], cd)
    y, h_last = _rg_lru(p, xc, state["h"], cd)
    out = jnp.einsum("bsl,ld->bsd", y * gate, p["w_out"].astype(cd))
    return out, {"h": h_last, "conv": conv_taps}


def recurrent_state_init(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    return {"h": jnp.zeros((batch, cfg.lru_dim), dtype),
            "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.lru_dim),
                              dtype)}


def recurrent_state_axes():
    return {"h": "batch mlp", "conv": "batch . mlp"}
