"""Mixture-of-Experts with scatter/gather capacity dispatch.

Paper tie-in (DESIGN.md §4): the token→expert assignment is a sparse
bipartite graph; dispatch/combine are exactly the graph processor's
Dispatch Logic (scatter) and Output Logic (gather), and the router's
balance objective is the paper's cluster load-balancing criterion.  Tokens
are processed in fixed-size *groups* (the clustering granularity): group
size trades capacity slack against locality, the same trade the paper's
node-cluster size makes against NALE FIFO depth.

Unlike the classic GShard (S,E,C)-one-hot dispatch — whose mask grows
quadratically with group size — dispatch here is a true scatter into a
per-group (E·C+1, D) capacity buffer (slot = expert·C + position; dropped
tokens land in the sink row), and combine is the weighted gather back.
Memory is tokens·k·cf·D, activation-scale.

Shardings: groups ride the batch axes (pod, data); experts ride "model"
(expert parallelism); the dispatch buffer resharding from G-local to
expert-parallel is the all_to_all the roofline tracks.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import layers
from ..sharding.rules import constrain


def moe_init(cfg: ModelConfig, key):
    ks = jax.random.split(key, 3)
    dt = layers.dtype_of(cfg.param_dtype)
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.num_experts

    def expert_bank(k):
        if cfg.mlp_kind == "swiglu":
            k1, k2, k3 = jax.random.split(k, 3)
            p = {"wi": layers._init(k1, (e, d, ff), d, dt),
                 "wg": layers._init(k2, (e, d, ff), d, dt),
                 "wo": layers._init(k3, (e, ff, d), ff, dt)}
            a = {"wi": "expert embed mlp", "wg": "expert embed mlp",
                 "wo": "expert mlp embed"}
        else:
            k1, k2 = jax.random.split(k, 2)
            p = {"wi": layers._init(k1, (e, d, ff), d, dt),
                 "wo": layers._init(k2, (e, ff, d), ff, dt)}
            a = {"wi": "expert embed mlp", "wo": "expert mlp embed"}
        return p, a

    pe, ae = expert_bank(ks[0])
    p = {"router": layers._init(ks[1], (d, e), d, jnp.float32),
         "experts": pe}
    a = {"router": "embed expert", "experts": ae}
    if cfg.shared_expert:
        ps, as_ = layers.mlp_init(cfg, ks[2])
        p["shared"] = ps
        a["shared"] = as_
    return p, a


def _expert_ffn(cfg: ModelConfig, p, x):
    """x: (G, E, C, D) → (G, E, C, D); E rides the 'model' axis (EP)."""
    cd = layers.dtype_of(cfg.compute_dtype)
    h = jnp.einsum("gecd,edf->gecf", x, p["wi"].astype(cd))
    if cfg.mlp_kind == "swiglu":
        g = jnp.einsum("gecd,edf->gecf", x, p["wg"].astype(cd))
        h = jax.nn.silu(g) * h
    elif cfg.mlp_kind == "squared_relu":
        h = jnp.square(jax.nn.relu(h))
    else:
        h = jax.nn.gelu(h)
    return jnp.einsum("gecf,efd->gecd", h, p["wo"].astype(cd))


def moe_apply(cfg: ModelConfig, p, x,
              dropless: bool = False) -> Tuple[jnp.ndarray, dict]:
    """x: (B, S, D).  Returns (out, aux) with router losses in aux.

    Capacity semantics: within each group, tokens beyond an expert's
    capacity are dropped (their residual passes through untouched).
    ``dropless=True`` sizes capacity to the worst case (decode path:
    a dropped token at decode time would corrupt generation)."""
    cd = layers.dtype_of(cfg.compute_dtype)
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.top_k
    gs = min(cfg.moe_group_size, b * s)
    tokens = x.reshape(-1, d)
    ng = tokens.shape[0] // gs
    xt = tokens[: ng * gs].reshape(ng, gs, d)
    xt = constrain(xt, "batch . .")

    logits = jnp.einsum("gsd,de->gse", xt.astype(jnp.float32), p["router"])
    gates = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(gates, k)              # (G, S, K)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

    cap = gs if dropless else int(max(1, gs * k * cfg.capacity_factor / e))
    # position of each (token, choice) within its expert's capacity,
    # priority order: all first choices, then second choices, ... (GShard)
    oh = jax.nn.one_hot(topi, e, dtype=jnp.float32)   # (G,S,K,E)
    flat = oh.transpose(0, 2, 1, 3).reshape(ng, k * gs, e)
    pos_flat = jnp.cumsum(flat, axis=1) - flat        # (G,K*S,E)
    pos = jnp.sum(pos_flat.reshape(ng, k, gs, e).transpose(0, 2, 1, 3)
                  * oh, axis=-1).astype(jnp.int32)    # (G,S,K)
    keep = pos < cap
    sink = e * cap                                    # drop slot
    slot = jnp.where(keep, topi * cap + pos, sink)    # (G,S,K)

    # --- Dispatch Logic: scatter tokens into per-group capacity buffers
    def scatter_group(xg, sg):
        upd = jnp.broadcast_to(xg[:, None, :], (gs, k, d)).reshape(-1, d)
        buf = jnp.zeros((e * cap + 1, d), cd)
        return buf.at[sg.reshape(-1)].add(upd.astype(cd))

    buf = jax.vmap(scatter_group)(xt, slot)           # (G, E*C+1, D)
    xin = buf[:, : e * cap].reshape(ng, e, cap, d)
    xin = constrain(xin, "batch expert . .")          # EP reshard
    xout = _expert_ffn(cfg, p["experts"], xin)        # (G,E,C,D)

    # --- Output Logic: gather weighted expert outputs back to tokens
    buf_out = jnp.concatenate(
        [xout.reshape(ng, e * cap, d),
         jnp.zeros((ng, 1, d), xout.dtype)], axis=1)  # sink row = 0

    def gather_group(bg, sg, wg):
        y = bg[sg.reshape(-1)].reshape(gs, k, d)
        return jnp.sum(y * wg[..., None].astype(y.dtype), axis=1)

    out = jax.vmap(gather_group)(buf_out, slot, topw)  # (G, S, D)
    out = constrain(out, "batch . .")

    if cfg.shared_expert:
        out = out + layers.mlp_apply(cfg, p["shared"], xt)

    out_flat = out.reshape(-1, d)
    if out_flat.shape[0] < tokens.shape[0]:  # group-size remainder
        out_flat = jnp.concatenate(
            [out_flat, tokens[out_flat.shape[0]:].astype(out_flat.dtype)],
            axis=0)
    out = out_flat.reshape(b, s, d)

    # load-balance aux (the cluster balance objective) + router z-loss
    me = jnp.mean(gates, axis=(0, 1))
    ce = jnp.mean(jax.nn.one_hot(topi[..., 0], e), axis=(0, 1))
    aux = cfg.router_aux_coef * e * jnp.sum(me * ce)
    z = cfg.router_z_coef * jnp.mean(
        jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    frac_dropped = 1.0 - jnp.mean(keep.astype(jnp.float32))
    return out, {"aux_loss": aux + z, "frac_dropped": frac_dropped,
                 "expert_load": ce}
