"""ComposableLM — config-driven decoder stack covering all assigned
families (dense GQA / MLA / MoE / RWKV / Griffin-hybrid / VLM cross-attn /
enc-dec audio).

Layers are organised as repeating *superblocks* (cfg.block_pattern) and
scanned with ``lax.scan`` so HLO size and compile time are depth-
independent — a 96-layer 340B model lowers as one superblock.  The pattern
remainder (e.g. RecurrentGemma's trailing 2 recurrent layers) is unrolled.

Three entry points per model:
  forward_train(params, batch)          → logits (+ aux losses)
  prefill(params, tokens, cache_len)    → last-token logits + cache
  decode_step(params, cache, token, pos)→ logits + new cache
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..sharding.rules import constrain
from . import cache as cache_lib
from . import griffin, layers, moe, rwkv


# ---------------------------------------------------------------------------
# block init / apply
# ---------------------------------------------------------------------------


def block_init(cfg: ModelConfig, kind: str, key):
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {}
    a: Dict[str, Any] = {}
    p["ln1"], a["ln1"] = layers.norm_init(cfg)
    if kind in ("attn", "local_attn", "moe", "decoder"):
        if cfg.attn_kind == "mla":
            p["attn"], a["attn"] = layers.mla_init(cfg, ks[0])
        else:
            p["attn"], a["attn"] = layers.attn_init(cfg, ks[0])
        p["ln2"], a["ln2"] = layers.norm_init(cfg)
        if kind == "moe":
            p["mlp"], a["mlp"] = moe.moe_init(cfg, ks[1])
        else:
            p["mlp"], a["mlp"] = layers.mlp_init(cfg, ks[1])
        if kind == "decoder":  # self + cross + mlp (whisper-style)
            p["xattn"], a["xattn"] = layers.attn_init(cfg, ks[2])
            p["ln_x"], a["ln_x"] = layers.norm_init(cfg)
    elif kind == "cross_attn":
        p["attn"], a["attn"] = layers.attn_init(cfg, ks[0])
        p["gate"] = jnp.zeros((), jnp.float32)  # tanh-gated (llama-vision)
        a["gate"] = ""
        p["ln2"], a["ln2"] = layers.norm_init(cfg)
        p["mlp"], a["mlp"] = layers.mlp_init(cfg, ks[1])
        p["gate_mlp"] = jnp.zeros((), jnp.float32)
        a["gate_mlp"] = ""
    elif kind == "rwkv":
        p["rwkv"], a["rwkv"] = rwkv.rwkv_init(cfg, ks[0])
        p["ln2"], a["ln2"] = layers.norm_init(cfg)
    elif kind == "recurrent":
        p["rec"], a["rec"] = griffin.recurrent_init(cfg, ks[0])
        p["ln2"], a["ln2"] = layers.norm_init(cfg)
        p["mlp"], a["mlp"] = layers.mlp_init(cfg, ks[1])
    else:
        raise ValueError(kind)
    return p, a


def block_apply_train(cfg: ModelConfig, kind: str, p, x, *, positions,
                      enc=None, attn_impl="ref"):
    """Full-sequence forward.  Returns (x, aux_loss)."""
    aux = jnp.float32(0.0)
    h = layers.norm_apply(cfg, p["ln1"], x)
    if kind in ("attn", "local_attn", "moe", "decoder"):
        window = cfg.window if kind == "local_attn" else None
        if cfg.attn_kind == "mla":
            att = layers.mla_apply(cfg, p["attn"], h, positions=positions,
                                   attn_impl=attn_impl)
        else:
            att = layers.attn_apply(cfg, p["attn"], h, positions=positions,
                                    window=window, attn_impl=attn_impl)
        x = x + att
        if kind == "decoder":
            hx = layers.norm_apply(cfg, p["ln_x"], x)
            x = x + layers.attn_apply(cfg, p["xattn"], hx,
                                      positions=positions, kv_src=enc,
                                      causal=False, attn_impl=attn_impl)
        h2 = layers.norm_apply(cfg, p["ln2"], x)
        if kind == "moe":
            mo, info = moe.moe_apply(cfg, p["mlp"], h2)
            aux = aux + info["aux_loss"]
            x = x + mo
        else:
            x = x + layers.mlp_apply(cfg, p["mlp"], h2)
    elif kind == "cross_attn":
        att = layers.attn_apply(cfg, p["attn"], h, positions=positions,
                                kv_src=enc, causal=False,
                                attn_impl=attn_impl)
        x = x + jnp.tanh(p["gate"]).astype(x.dtype) * att
        h2 = layers.norm_apply(cfg, p["ln2"], x)
        x = x + jnp.tanh(p["gate_mlp"]).astype(x.dtype) \
            * layers.mlp_apply(cfg, p["mlp"], h2)
    elif kind == "rwkv":
        state = rwkv.rwkv_state_init(cfg, x.shape[0],
                                     layers.dtype_of(cfg.compute_dtype))
        tm, _, _ = rwkv.rwkv_time_mix(cfg, p["rwkv"], h, state["wkv"],
                                      state["tm_x"])
        x = x + tm
        h2 = layers.norm_apply(cfg, p["ln2"], x)
        cm, _ = rwkv.rwkv_channel_mix(cfg, p["rwkv"], h2, state["cm_x"])
        x = x + cm
    elif kind == "recurrent":
        state = griffin.recurrent_state_init(cfg, x.shape[0])
        ro, _ = griffin.recurrent_apply(cfg, p["rec"], h, state)
        x = x + ro
        h2 = layers.norm_apply(cfg, p["ln2"], x)
        x = x + layers.mlp_apply(cfg, p["mlp"], h2)
    else:
        raise ValueError(kind)
    return x, aux


def block_prefill(cfg: ModelConfig, kind: str, p, x, *, positions,
                  cache_len: int, enc=None, attn_impl="ref"):
    """Forward + build this block's decode cache."""
    h = layers.norm_apply(cfg, p["ln1"], x)
    if kind in ("attn", "local_attn", "moe", "decoder"):
        if cfg.attn_kind == "mla":
            att, c = layers.mla_prefill(cfg, p["attn"], h,
                                        positions=positions,
                                        cache_len=cache_len,
                                        attn_impl=attn_impl)
        elif kind == "local_attn":
            att, c = _local_prefill(cfg, p["attn"], h, positions,
                                    attn_impl)
        else:
            att, c = layers.attn_prefill(cfg, p["attn"], h,
                                         positions=positions,
                                         cache_len=cache_len,
                                         attn_impl=attn_impl)
        x = x + att
        if kind == "decoder":
            hx = layers.norm_apply(cfg, p["ln_x"], x)
            x = x + layers.attn_apply(cfg, p["xattn"], hx,
                                      positions=positions, kv_src=enc,
                                      causal=False, attn_impl=attn_impl)
            ckv = layers.cross_attn_kv(cfg, p["xattn"], enc)
            c = {"self": c, "cross_k": ckv["k"], "cross_v": ckv["v"]}
        h2 = layers.norm_apply(cfg, p["ln2"], x)
        if kind == "moe":
            mo, _ = moe.moe_apply(cfg, p["mlp"], h2)
            x = x + mo
        else:
            x = x + layers.mlp_apply(cfg, p["mlp"], h2)
        return x, c
    if kind == "cross_attn":
        att = layers.attn_apply(cfg, p["attn"], h, positions=positions,
                                kv_src=enc, causal=False,
                                attn_impl=attn_impl)
        x = x + jnp.tanh(p["gate"]).astype(x.dtype) * att
        h2 = layers.norm_apply(cfg, p["ln2"], x)
        x = x + jnp.tanh(p["gate_mlp"]).astype(x.dtype) \
            * layers.mlp_apply(cfg, p["mlp"], h2)
        ckv = layers.cross_attn_kv(cfg, p["attn"], enc)
        return x, {"k": ckv["k"], "v": ckv["v"]}
    if kind == "rwkv":
        state = rwkv.rwkv_state_init(cfg, x.shape[0],
                                     layers.dtype_of(cfg.compute_dtype))
        tm, wkv_s, tm_x = rwkv.rwkv_time_mix(cfg, p["rwkv"], h,
                                             state["wkv"], state["tm_x"])
        x = x + tm
        h2 = layers.norm_apply(cfg, p["ln2"], x)
        cm, cm_x = rwkv.rwkv_channel_mix(cfg, p["rwkv"], h2,
                                         state["cm_x"])
        x = x + cm
        return x, {"wkv": wkv_s.astype(jnp.float32),
                   "tm_x": tm_x.astype(jnp.float32),
                   "cm_x": cm_x.astype(jnp.float32)}
    if kind == "recurrent":
        state = griffin.recurrent_state_init(cfg, x.shape[0])
        ro, st = griffin.recurrent_apply(cfg, p["rec"], h, state)
        x = x + ro
        h2 = layers.norm_apply(cfg, p["ln2"], x)
        x = x + layers.mlp_apply(cfg, p["mlp"], h2)
        return x, jax.tree.map(lambda t: t.astype(jnp.float32), st)
    raise ValueError(kind)


def _local_prefill(cfg, p, h, positions, attn_impl):
    """Local attention prefill: compute windowed attention, keep only the
    last ``window`` K/V in a ring buffer (slot = pos % window)."""
    import numpy as np
    att_full, full_cache = layers.attn_prefill(
        cfg, p, h, positions=positions, cache_len=h.shape[1],
        window=cfg.window, attn_impl=attn_impl)
    s, w = h.shape[1], cfg.window
    b = h.shape[0]
    kfull, vfull = full_cache["k"], full_cache["v"]
    keep_from = max(0, s - w)
    times = np.arange(keep_from, s)          # static: last min(s,w) tokens
    slots = times % w                        # unique (consecutive ints)
    k = jnp.zeros((b, w) + kfull.shape[2:], kfull.dtype)
    v = jnp.zeros_like(k)
    pos_of_slot = jnp.full((b, w), -1, jnp.int32)
    k = k.at[:, slots].set(kfull[:, times])
    v = v.at[:, slots].set(vfull[:, times])
    pos_of_slot = pos_of_slot.at[:, slots].set(
        jnp.asarray(times, jnp.int32)[None])
    return att_full, {"k": k, "v": v, "pos_of_slot": pos_of_slot}


def block_decode(cfg: ModelConfig, kind: str, p, x, c, *, pos,
                 attn_impl="ref"):
    """One-token step.  Returns (x, new_cache)."""
    h = layers.norm_apply(cfg, p["ln1"], x)
    if kind in ("attn", "moe", "decoder"):
        cc = c["self"] if kind == "decoder" else c
        if cfg.attn_kind == "mla":
            att, cc = layers.mla_decode(cfg, p["attn"], h, cc, pos=pos)
        else:
            att, cc = layers.attn_decode(cfg, p["attn"], h, cc, pos=pos)
        x = x + att
        if kind == "decoder":
            hx = layers.norm_apply(cfg, p["ln_x"], x)
            x = x + layers.cross_attn_decode(
                cfg, p["xattn"], hx, {"k": c["cross_k"], "v": c["cross_v"]})
            c = {"self": cc, "cross_k": c["cross_k"],
                 "cross_v": c["cross_v"]}
        else:
            c = cc
        h2 = layers.norm_apply(cfg, p["ln2"], x)
        if kind == "moe":
            mo, _ = moe.moe_apply(cfg, p["mlp"], h2, dropless=True)
            x = x + mo
        else:
            x = x + layers.mlp_apply(cfg, p["mlp"], h2)
        return x, c
    if kind == "local_attn":
        att, c = _local_decode(cfg, p["attn"], h, c, pos)
        x = x + att
        h2 = layers.norm_apply(cfg, p["ln2"], x)
        x = x + layers.mlp_apply(cfg, p["mlp"], h2)
        return x, c
    if kind == "cross_attn":
        att = layers.cross_attn_decode(cfg, p["attn"], h, c)
        x = x + jnp.tanh(p["gate"]).astype(x.dtype) * att
        h2 = layers.norm_apply(cfg, p["ln2"], x)
        x = x + jnp.tanh(p["gate_mlp"]).astype(x.dtype) \
            * layers.mlp_apply(cfg, p["mlp"], h2)
        return x, c
    if kind == "rwkv":
        tm, wkv_s, tm_x = rwkv.rwkv_time_mix(
            cfg, p["rwkv"], h, c["wkv"].astype(h.dtype), c["tm_x"])
        x = x + tm
        h2 = layers.norm_apply(cfg, p["ln2"], x)
        cm, cm_x = rwkv.rwkv_channel_mix(cfg, p["rwkv"], h2, c["cm_x"])
        x = x + cm
        return x, {"wkv": wkv_s.astype(jnp.float32),
                   "tm_x": tm_x.astype(jnp.float32),
                   "cm_x": cm_x.astype(jnp.float32)}
    if kind == "recurrent":
        ro, st = griffin.recurrent_apply(cfg, p["rec"], h, c)
        x = x + ro
        h2 = layers.norm_apply(cfg, p["ln2"], x)
        x = x + layers.mlp_apply(cfg, p["mlp"], h2)
        return x, jax.tree.map(lambda t: t.astype(jnp.float32), st)
    raise ValueError(kind)


def _local_decode(cfg, p, h, c, pos):
    """Ring-buffer local attention decode (O(window) memory)."""
    cd = layers.dtype_of(cfg.compute_dtype)
    b = h.shape[0]
    w = cfg.window
    pos_arr = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"].astype(cd))
    k_new = jnp.einsum("bsd,dhk->bshk", h, p["wk"].astype(cd))
    v_new = jnp.einsum("bsd,dhk->bshk", h, p["wv"].astype(cd))
    if cfg.pos_embedding == "rope":
        q = layers.apply_rope(q, pos_arr[:, None], cfg.rope_theta,
                              cfg.rope_fraction)
        k_new = layers.apply_rope(k_new, pos_arr[:, None], cfg.rope_theta,
                                  cfg.rope_fraction)
    slot = pos_arr % w
    onehot = (jnp.arange(w, dtype=jnp.int32)[None] == slot[:, None])
    oh = onehot[:, :, None, None].astype(c["k"].dtype)
    k = c["k"] * (1 - oh) + oh * k_new.astype(c["k"].dtype)
    v = c["v"] * (1 - oh) + oh * v_new.astype(c["v"].dtype)
    pos_of_slot = jnp.where(onehot, pos_arr[:, None], c["pos_of_slot"])
    # attend over valid slots
    kk, vv = k, v
    hh, kvh = q.shape[2], kk.shape[2]
    if kvh != hh:
        kk = jnp.repeat(kk, hh // kvh, axis=2)
        vv = jnp.repeat(vv, hh // kvh, axis=2)
    scale = 1.0 / (q.shape[-1] ** 0.5)
    s = jnp.einsum("bthk,bshk->bhts", q, kk).astype(jnp.float32) * scale
    tpos = pos_of_slot[:, None, None, :]
    mask = (tpos >= 0) & (tpos <= pos_arr[:, None, None, None]) & \
        (tpos > pos_arr[:, None, None, None] - w)
    s = jnp.where(mask, s, -jnp.inf)
    o = jnp.einsum("bhts,bshk->bthk",
                   jax.nn.softmax(s, -1).astype(vv.dtype), vv)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(cd))
    return out, {"k": k, "v": v, "pos_of_slot": pos_of_slot}


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------


def init(cfg: ModelConfig, key) -> Tuple[Dict, Dict]:
    """Returns (params, axes).  Scanned superblock params are stacked on a
    leading 'stack' axis; remainder blocks are separate."""
    ks = jax.random.split(key, 8)
    dt = layers.dtype_of(cfg.param_dtype)
    p: Dict[str, Any] = {
        "embed": layers._init(ks[0], (cfg.vocab_size, cfg.d_model),
                              cfg.d_model, dt),
    }
    a: Dict[str, Any] = {"embed": "vocab embed"}
    if not cfg.tie_embeddings:
        p["head"] = layers._init(ks[1], (cfg.d_model, cfg.vocab_size),
                                 cfg.d_model, dt)
        a["head"] = "embed vocab"
    p["ln_f"], a["ln_f"] = layers.norm_init(cfg)
    if cfg.pos_embedding == "learned":
        p["pos_emb"] = layers._init(ks[2], (cfg.max_seq, cfg.d_model),
                                    cfg.d_model, dt)
        a["pos_emb"] = ". embed"
    if cfg.img_seq:  # vision stub projection (frontend embeddings → d)
        p["img_proj"] = layers._init(ks[3], (cfg.d_model, cfg.d_model),
                                     cfg.d_model, dt)
        a["img_proj"] = "embed embed2"

    reps = cfg.pattern_repeats
    pat = cfg.block_pattern

    def init_pos(j, kind):
        def one(k):
            return block_init(cfg, kind, k)[0]
        keys = jax.random.split(jax.random.fold_in(ks[4], j), reps)
        stacked = jax.jit(lambda kk: jax.vmap(one)(kk))(keys)
        _, ax = block_init(cfg, kind, keys[0])
        ax = jax.tree.map(lambda s: ("stack " + s).strip(), ax)
        return stacked, ax

    sb_p, sb_a = {}, {}
    for j, kind in enumerate(pat):
        sb_p[f"b{j}"], sb_a[f"b{j}"] = init_pos(j, kind)
    p["blocks"] = sb_p
    a["blocks"] = sb_a

    rem_p, rem_a = {}, {}
    for j, kind in enumerate(cfg.remainder_layers):
        rem_p[f"r{j}"], rem_a[f"r{j}"] = block_init(
            cfg, kind, jax.random.fold_in(ks[5], 1000 + j))
    if rem_p:
        p["rem"] = rem_p
        a["rem"] = rem_a

    if cfg.encdec:
        enc_p, enc_a = {}, {}

        def enc_one(k):
            return block_init(cfg, "attn", k)[0]
        keys = jax.random.split(ks[6], cfg.encoder_layers)
        enc_p["blocks"] = jax.jit(lambda kk: jax.vmap(enc_one)(kk))(keys)
        _, ax = block_init(cfg, "attn", keys[0])
        enc_a["blocks"] = jax.tree.map(lambda s: ("stack " + s).strip(), ax)
        enc_p["ln_f"], enc_a["ln_f"] = layers.norm_init(cfg)
        if cfg.pos_embedding == "learned":
            enc_p["pos_emb"] = layers._init(
                ks[7], (cfg.encoder_seq, cfg.d_model), cfg.d_model, dt)
            enc_a["pos_emb"] = ". embed"
        p["encoder"] = enc_p
        a["encoder"] = enc_a
    return p, a


def _embed(cfg, p, tokens):
    cd = layers.dtype_of(cfg.compute_dtype)
    x = p["embed"].astype(cd)[tokens]
    return constrain(x, "batch . .")


def _logits(cfg, p, x):
    cd = layers.dtype_of(cfg.compute_dtype)
    x = layers.norm_apply(cfg, p["ln_f"], x)
    if cfg.tie_embeddings:
        return x @ p["embed"].astype(cd).T
    return x @ p["head"].astype(cd)


def encode(cfg: ModelConfig, p, enc_embeds):
    """Run the (whisper) encoder over stub frame embeddings."""
    cd = layers.dtype_of(cfg.compute_dtype)
    ep = p["encoder"]
    x = enc_embeds.astype(cd)
    if cfg.pos_embedding == "learned":
        x = x + ep["pos_emb"].astype(cd)[None, : x.shape[1]]
    positions = jnp.broadcast_to(
        jnp.arange(x.shape[1], dtype=jnp.int32)[None], x.shape[:2])

    def body(x, pl):
        h = layers.norm_apply(cfg, pl["ln1"], x)
        att = layers.attn_apply(cfg, pl["attn"], h, positions=positions,
                                causal=False)
        x = x + att
        h2 = layers.norm_apply(cfg, pl["ln2"], x)
        x = x + layers.mlp_apply(cfg, pl["mlp"], h2)
        return x, None

    x, _ = jax.lax.scan(body, x, ep["blocks"])
    return layers.norm_apply(cfg, ep["ln_f"], x)


def _enc_for(cfg, p, batch: Dict):
    """Resolve the cross-attention source (image / audio stub)."""
    cd = layers.dtype_of(cfg.compute_dtype)
    if cfg.encdec:
        return encode(cfg, p, batch["enc_embeds"])
    if cfg.img_seq:
        img = batch["img_embeds"].astype(cd)
        return img @ p["img_proj"].astype(cd)
    return None


def forward_train(cfg: ModelConfig, p, batch: Dict, attn_impl="ref",
                  sb_param_shardings=None):
    """batch: tokens (B,S) [+ img_embeds / enc_embeds stubs].
    Returns (logits, aux_loss).

    sb_param_shardings: optional NamedSharding pytree for ONE superblock
    slice.  Constraining the slice INSIDE the scan body pins the per-layer
    gradient sharding too (with_sharding_constraint is its own transpose),
    teaching GSPMD to reduce-scatter weight grads instead of all-reducing
    them at full shape inside the backward while-loop (EXPERIMENTS §Perf).
    """
    tokens = batch["tokens"]
    x = _embed(cfg, p, tokens)
    if cfg.pos_embedding == "learned":
        x = x + p["pos_emb"].astype(x.dtype)[None, : x.shape[1]]
    positions = jnp.broadcast_to(
        jnp.arange(x.shape[1], dtype=jnp.int32)[None], tokens.shape)
    enc = _enc_for(cfg, p, batch)
    pat = cfg.block_pattern

    def superblock(x, pslice):
        if sb_param_shardings is not None:
            pslice = jax.lax.with_sharding_constraint(
                pslice, sb_param_shardings)
        if cfg.shard_seq_boundary:
            # the remat-saved buffer is this block input: shard its seq dim
            # over the model axis (Megatron-style sequence parallelism)
            x = constrain(x, "batch seq_model .")
        aux = jnp.float32(0.0)
        for j, kind in enumerate(pat):
            x, a_ = block_apply_train(cfg, kind, pslice[f"b{j}"], x,
                                      positions=positions, enc=enc,
                                      attn_impl=attn_impl)
            aux = aux + a_
        return x, aux

    rg = cfg.remat_group
    reps = cfg.pattern_repeats
    if rg > 1 and reps % rg == 0:
        # 2-level checkpointing: the group saves only its input (÷rg
        # boundary activations); each superblock inside is ALSO
        # checkpointed, so a group's backward holds one layer's internals
        # at a time.  Forward is computed 3× total — the standard
        # deep-stack memory/recompute trade (DESIGN.md §8).
        inner = jax.checkpoint(
            superblock, policy=jax.checkpoint_policies.nothing_saveable) \
            if cfg.remat else superblock

        def group(x, pg):
            aux = jnp.float32(0.0)
            for i in range(rg):
                x, a_ = inner(x, jax.tree.map(lambda t: t[i], pg))
                aux = aux + a_
            return x, aux

        stacked = jax.tree.map(
            lambda t: t.reshape((reps // rg, rg) + t.shape[1:]),
            p["blocks"])
        gb = group
        if cfg.remat:
            gb = jax.checkpoint(
                group, policy=jax.checkpoint_policies.nothing_saveable)
        x, auxs = jax.lax.scan(gb, x, stacked)
    else:
        sb = superblock
        if cfg.remat:
            sb = jax.checkpoint(
                superblock, policy=jax.checkpoint_policies.nothing_saveable)
        x, auxs = jax.lax.scan(sb, x, p["blocks"])
    aux = jnp.sum(auxs)
    for j, kind in enumerate(cfg.remainder_layers):
        x, a_ = block_apply_train(cfg, kind, p["rem"][f"r{j}"], x,
                                  positions=positions, enc=enc,
                                  attn_impl=attn_impl)
        aux = aux + a_
    return _logits(cfg, p, x), aux


def loss_fn(cfg: ModelConfig, p, batch: Dict, attn_impl="ref",
            sb_param_shardings=None):
    logits, aux = forward_train(cfg, p, batch, attn_impl,
                                sb_param_shardings=sb_param_shardings)
    labels = batch["labels"]
    lf = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    mask = batch.get("loss_mask")
    if mask is None:
        mask = jnp.ones_like(ll)
    denom = jnp.maximum(mask.sum(), 1.0)
    ce = jnp.sum((logz - ll) * mask) / denom
    zloss = 1e-4 * jnp.sum(jnp.square(logz) * mask) / denom
    total = ce + zloss + aux
    return total, {"ce": ce, "zloss": zloss, "aux": aux,
                   "ppl": jnp.exp(ce)}


def init_cache(cfg: ModelConfig, batch: int, cache_len: int,
               dtype=jnp.bfloat16):
    """Stacked (scan-compatible) cache pytree + its logical axes."""
    reps = cfg.pattern_repeats

    def stack_init(kind):
        one = cache_lib.block_cache_init(cfg, kind, batch, cache_len, dtype)
        return jax.tree.map(
            lambda l: jnp.broadcast_to(l[None], (reps,) + l.shape), one)

    c = {"blocks": {f"b{j}": stack_init(kind)
                    for j, kind in enumerate(cfg.block_pattern)}}
    if cfg.remainder_layers:
        c["rem"] = {f"r{j}": cache_lib.block_cache_init(cfg, kind, batch,
                                                        cache_len, dtype)
                    for j, kind in enumerate(cfg.remainder_layers)}
    return c


def cache_axes(cfg: ModelConfig):
    c = {"blocks": {
        f"b{j}": jax.tree.map(lambda s: ("stack " + s).strip(),
                              cache_lib.block_cache_axes(cfg, kind))
        for j, kind in enumerate(cfg.block_pattern)}}
    if cfg.remainder_layers:
        c["rem"] = {f"r{j}": cache_lib.block_cache_axes(cfg, kind)
                    for j, kind in enumerate(cfg.remainder_layers)}
    return c


def prefill(cfg: ModelConfig, p, batch: Dict, cache_len: int,
            attn_impl="ref"):
    """Returns (last_logits (B,V), cache)."""
    tokens = batch["tokens"]
    x = _embed(cfg, p, tokens)
    if cfg.pos_embedding == "learned":
        x = x + p["pos_emb"].astype(x.dtype)[None, : x.shape[1]]
    positions = jnp.broadcast_to(
        jnp.arange(x.shape[1], dtype=jnp.int32)[None], tokens.shape)
    enc = _enc_for(cfg, p, batch)
    pat = cfg.block_pattern

    def superblock(x, pslice):
        caches = {}
        for j, kind in enumerate(pat):
            x, c = block_prefill(cfg, kind, pslice[f"b{j}"], x,
                                 positions=positions, cache_len=cache_len,
                                 enc=enc, attn_impl=attn_impl)
            caches[f"b{j}"] = c
        return x, caches

    x, caches = jax.lax.scan(superblock, x, p["blocks"])
    out = {"blocks": caches}
    if cfg.remainder_layers:
        rem = {}
        for j, kind in enumerate(cfg.remainder_layers):
            x, c = block_prefill(cfg, kind, p["rem"][f"r{j}"], x,
                                 positions=positions, cache_len=cache_len,
                                 enc=enc, attn_impl=attn_impl)
            rem[f"r{j}"] = c
        out["rem"] = rem
    logits = _logits(cfg, p, x[:, -1:, :])[:, 0]
    return logits, out


def decode_step(cfg: ModelConfig, p, cache, token, pos, attn_impl="ref"):
    """token: (B,) int32; pos: scalar or (B,) current position.
    Returns (logits (B,V), new_cache)."""
    x = _embed(cfg, p, token[:, None])
    b = token.shape[0]
    pos_arr = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    if cfg.pos_embedding == "learned":
        x = x + p["pos_emb"].astype(x.dtype)[pos_arr][:, None]
    pat = cfg.block_pattern

    def superblock(x, scanned):
        pslice, cslice = scanned
        new_c = {}
        for j, kind in enumerate(pat):
            x, c = block_decode(cfg, kind, pslice[f"b{j}"], x,
                                cslice[f"b{j}"], pos=pos_arr,
                                attn_impl=attn_impl)
            new_c[f"b{j}"] = c
        return x, new_c

    x, new_blocks = jax.lax.scan(superblock, x,
                                 (p["blocks"], cache["blocks"]))
    new_cache = {"blocks": new_blocks}
    if cfg.remainder_layers:
        rem = {}
        for j, kind in enumerate(cfg.remainder_layers):
            x, c = block_decode(cfg, kind, p["rem"][f"r{j}"], x,
                                cache["rem"][f"r{j}"], pos=pos_arr,
                                attn_impl=attn_impl)
            rem[f"r{j}"] = c
        new_cache["rem"] = rem
    logits = _logits(cfg, p, x)[:, 0]
    return logits, new_cache


_ = (functools, Optional)
