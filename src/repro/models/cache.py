"""Decode-state containers: KV caches (full, ring-buffered local, MLA
latent, cross-attn) and recurrent states (RWKV, RG-LRU).

Local-attention caches are ring buffers of size ``window`` with an
explicit ``pos_of_slot`` time map — O(window) memory, which is what makes
``long_500k`` decoding tractable for the hybrid/SSM architectures."""

from __future__ import annotations

import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import griffin, rwkv


def attn_cache_init(cfg: ModelConfig, batch: int, cache_len: int,
                    dtype=jnp.bfloat16):
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    return {"k": jnp.zeros((batch, cache_len, kv, hd), dtype),
            "v": jnp.zeros((batch, cache_len, kv, hd), dtype)}


def attn_cache_axes():
    return {"k": "batch kv_seq kv_heads head_dim",
            "v": "batch kv_seq kv_heads head_dim"}


def local_cache_init(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    w = cfg.window
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    return {"k": jnp.zeros((batch, w, kv, hd), dtype),
            "v": jnp.zeros((batch, w, kv, hd), dtype),
            "pos_of_slot": jnp.full((batch, w), -1, jnp.int32)}


def local_cache_axes():
    return {"k": "batch . kv_heads head_dim",
            "v": "batch . kv_heads head_dim",
            "pos_of_slot": "batch ."}


def mla_cache_init(cfg: ModelConfig, batch: int, cache_len: int,
                   dtype=jnp.bfloat16):
    return {"c_kv": jnp.zeros((batch, cache_len, cfg.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, cache_len, cfg.qk_rope_dim), dtype)}


def mla_cache_axes():
    return {"c_kv": "batch kv_seq .", "k_rope": "batch kv_seq ."}


def block_cache_init(cfg: ModelConfig, kind: str, batch: int,
                     cache_len: int, dtype=jnp.bfloat16):
    if kind in ("attn", "moe", "decoder"):
        if cfg.attn_kind == "mla":
            c = mla_cache_init(cfg, batch, cache_len, dtype)
        else:
            c = attn_cache_init(cfg, batch, cache_len, dtype)
        if kind == "decoder":  # + static cross K/V filled at prefill
            c = {"self": c,
                 "cross_k": jnp.zeros((batch, cfg.encoder_seq,
                                       cfg.num_kv_heads, cfg.head_dim),
                                      dtype),
                 "cross_v": jnp.zeros((batch, cfg.encoder_seq,
                                       cfg.num_kv_heads, cfg.head_dim),
                                      dtype)}
        return c
    if kind == "local_attn":
        return local_cache_init(cfg, batch, dtype)
    if kind == "cross_attn":
        return {"k": jnp.zeros((batch, cfg.img_seq, cfg.num_kv_heads,
                                cfg.head_dim), dtype),
                "v": jnp.zeros((batch, cfg.img_seq, cfg.num_kv_heads,
                                cfg.head_dim), dtype)}
    if kind == "rwkv":
        return rwkv.rwkv_state_init(cfg, batch, jnp.float32)
    if kind == "recurrent":
        return griffin.recurrent_state_init(cfg, batch, jnp.float32)
    raise ValueError(kind)


def block_cache_axes(cfg: ModelConfig, kind: str):
    if kind in ("attn", "moe", "decoder"):
        c = mla_cache_axes() if cfg.attn_kind == "mla" else attn_cache_axes()
        if kind == "decoder":
            return {"self": c,
                    "cross_k": "batch enc_seq kv_heads head_dim",
                    "cross_v": "batch enc_seq kv_heads head_dim"}
        return c
    if kind == "local_attn":
        return local_cache_axes()
    if kind == "cross_attn":
        return {"k": "batch img_seq kv_heads head_dim",
                "v": "batch img_seq kv_heads head_dim"}
    if kind == "rwkv":
        return rwkv.rwkv_state_axes()
    if kind == "recurrent":
        return griffin.recurrent_state_axes()
    raise ValueError(kind)
