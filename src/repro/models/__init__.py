from . import cache, griffin, layers, lm, moe, rwkv  # noqa: F401
