"""Transformer building blocks (functional: init returns (params, axes)).

Params are plain pytrees; the parallel ``axes`` pytree holds logical-axis
strings (see sharding/rules.py) consumed by the launcher to build
NamedShardings.  Compute runs in cfg.compute_dtype (bf16 by default),
params are kept in cfg.param_dtype (f32 master).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

# jax.shard_map landed in 0.5.x; this container ships 0.4.x
_shard_map = getattr(jax, "shard_map", None)
if _shard_map is None:  # pragma: no cover - version dependent
    from jax.experimental.shard_map import shard_map as _shard_map

from ..configs.base import ModelConfig
from ..kernels import ops
from ..sharding.rules import constrain


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


def _init(key, shape, scale_dim, dtype):
    return (jax.random.normal(key, shape, dtype=jnp.float32)
            * (scale_dim ** -0.5)).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def norm_init(cfg: ModelConfig, d: Optional[int] = None):
    d = d or cfg.d_model
    if cfg.norm == "layernorm":
        return ({"scale": jnp.ones((d,), jnp.float32),
                 "bias": jnp.zeros((d,), jnp.float32)},
                {"scale": "norm", "bias": "norm"})
    return ({"scale": jnp.ones((d,), jnp.float32)}, {"scale": "norm"})


def norm_apply(cfg: ModelConfig, p, x):
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + 1e-5) * p["scale"] + p["bias"]
    else:
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + 1e-6) * p["scale"]
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE (full / partial-fraction "2d")
# ---------------------------------------------------------------------------


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
               fraction: float = 1.0) -> jnp.ndarray:
    """x: (B, S, H, D); positions: (B, S).  Rotates the first
    ``fraction`` of D (chatglm-style 2d/partial rotary when < 1)."""
    d = x.shape[-1]
    rot = int(d * fraction) // 2 * 2
    if rot == 0:
        return x
    xr, xp = x[..., :rot], x[..., rot:]
    half = rot // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B,S,half)
    cos = jnp.cos(ang)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(ang)[:, :, None, :].astype(x.dtype)
    x1, x2 = xr[..., :half], xr[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                          axis=-1)
    return jnp.concatenate([out, xp], axis=-1) if rot < d else out


# ---------------------------------------------------------------------------
# GQA attention (self / cross / local)
# ---------------------------------------------------------------------------


def attn_init(cfg: ModelConfig, key, cross: bool = False):
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    dt = dtype_of(cfg.param_dtype)
    p = {
        "wq": _init(ks[0], (d, h, hd), d, dt),
        "wk": _init(ks[1], (d, kv, hd), d, dt),
        "wv": _init(ks[2], (d, kv, hd), d, dt),
        "wo": _init(ks[3], (h, hd, d), h * hd, dt),
    }
    a = {"wq": "embed heads head_dim", "wk": "embed_kv kv_heads head_dim",
         "wv": "embed_kv kv_heads head_dim", "wo": "heads head_dim embed"}
    return p, a


def _qkv(cfg, p, x, kv_src, positions, rope: bool):
    cd = dtype_of(cfg.compute_dtype)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(cd))
    k = jnp.einsum("bsd,dhk->bshk", kv_src, p["wk"].astype(cd))
    v = jnp.einsum("bsd,dhk->bshk", kv_src, p["wv"].astype(cd))
    if rope and cfg.pos_embedding == "rope":
        q = apply_rope(q, positions, cfg.rope_theta, cfg.rope_fraction)
        kpos = jnp.broadcast_to(
            jnp.arange(k.shape[1], dtype=jnp.int32)[None], k.shape[:2])
        k = apply_rope(k, kpos, cfg.rope_theta, cfg.rope_fraction)
    return q, k, v


def attn_apply(cfg: ModelConfig, p, x, *, positions, window=None,
               causal=True, kv_src=None, attn_impl="ref"):
    """Full-sequence attention (train / prefill).  kv_src ≠ None → cross."""
    cross = kv_src is not None
    kv_in = kv_src if cross else x
    q, k, v = _qkv(cfg, p, x, kv_in, positions, rope=not cross)
    o = ops.attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                      v.transpose(0, 2, 1, 3),
                      causal=causal and not cross,
                      window=window, impl=attn_impl)
    o = o.transpose(0, 2, 1, 3)  # (B, S, H, hd)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(o.dtype))


def attn_prefill(cfg: ModelConfig, p, x, *, positions, window=None,
                 cache_len: int, attn_impl="ref"):
    """Prefill: returns (out, cache{k,v}) with cache padded to cache_len."""
    q, k, v = _qkv(cfg, p, x, x, positions, rope=True)
    o = ops.attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                      v.transpose(0, 2, 1, 3), causal=True, window=window,
                      impl=attn_impl)
    o = o.transpose(0, 2, 1, 3)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(o.dtype))
    s = x.shape[1]
    pad = [(0, 0), (0, cache_len - s), (0, 0), (0, 0)]
    cache = {"k": jnp.pad(k, pad), "v": jnp.pad(v, pad)}
    return out, cache


def attn_decode(cfg: ModelConfig, p, x, cache, *, pos, window=None):
    """One-token decode against a (B, S_max, KV, hd) cache.  ``pos`` is the
    index of the new token (B,) or scalar."""
    cd = dtype_of(cfg.compute_dtype)
    b = x.shape[0]
    pos_arr = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(cd))
    k_new = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(cd))
    v_new = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(cd))
    if cfg.pos_embedding == "rope":
        q = apply_rope(q, pos_arr[:, None], cfg.rope_theta,
                       cfg.rope_fraction)
        k_new = apply_rope(k_new, pos_arr[:, None], cfg.rope_theta,
                           cfg.rope_fraction)
    k = _scatter_time(cache["k"], k_new, pos_arr)
    v = _scatter_time(cache["v"], v_new, pos_arr)
    o = _decode_attend(cfg, q, k, v, pos_arr, window)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(o.dtype))
    return out, {"k": k, "v": v}


def _scatter_time(cache, new, pos):
    """cache (B, S, KV, hd) ← new (B, 1, KV, hd) at per-batch pos."""
    b, s = cache.shape[:2]
    onehot = (jnp.arange(s, dtype=jnp.int32)[None] == pos[:, None])
    onehot = onehot[:, :, None, None].astype(cache.dtype)
    return cache * (1 - onehot) + onehot * new.astype(cache.dtype)


def _decode_attend(cfg, q, k, v, pos, window=None):
    """q (B,1,H,hd); k,v (B,S,KV,hd); masked softmax over cached length.

    When a production mesh is active and the KV cache is long enough to
    be seq-sharded over the model axis, uses the explicit flash-decoding
    path — otherwise GSPMD all-gathers the ENTIRE cache every step
    (measured: 43.9 GB/step for granite decode_32k; EXPERIMENTS.md §Perf).
    """
    from ..sharding.rules import _current_mesh
    mesh = _current_mesh()
    s_len = k.shape[1]
    if (mesh is not None and "model" in mesh.shape
            and s_len % mesh.shape["model"] == 0 and s_len >= 4096):
        return _decode_attend_flash(cfg, q, k, v, pos, window, mesh)
    return _decode_attend_local(q, k, v, pos, window, base=None)


def _decode_attend_local(q, k, v, pos, window, base):
    """Single-shard masked attend.  ``base``: global position of this
    shard's first cache slot (None → 0, full cache)."""
    h, kvh = q.shape[2], k.shape[2]
    if kvh != h:
        k = jnp.repeat(k, h // kvh, axis=2)
        v = jnp.repeat(v, h // kvh, axis=2)
    scale = 1.0 / (q.shape[-1] ** 0.5)
    s = jnp.einsum("bthk,bshk->bhts", q, k).astype(jnp.float32) * scale
    kpos = jnp.arange(k.shape[1], dtype=jnp.int32)[None, None, None, :]
    if base is not None:
        kpos = kpos + base
    mask = kpos <= pos[:, None, None, None]
    if window is not None:
        mask &= kpos > pos[:, None, None, None] - window
    s = jnp.where(mask, s, -jnp.inf)
    if base is None:
        pda = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bhts,bshk->bthk", pda.astype(v.dtype), v)
    # flash-decoding partial: return (o_unnormalized, m, l)
    m = jnp.max(s, axis=-1)                               # (B,H,1)
    p = jnp.exp(s - m[..., None])
    p = jnp.where(jnp.isfinite(s), p, 0.0)
    l_ = jnp.sum(p, axis=-1)                              # (B,H,1)
    o = jnp.einsum("bhts,bshk->bthk", p.astype(v.dtype), v)
    return o, m, l_


def _decode_attend_flash(cfg, q, k, v, pos, window, mesh):
    """Distributed flash-decoding: each model-shard attends over its LOCAL
    cache chunk, then combines (max, sum, weighted-V) with tiny psums —
    O(B·H·hd) collective instead of O(B·S·KV·hd) cache all-gather."""
    import functools
    from jax.sharding import PartitionSpec as P
    from ..sharding.rules import spec_for
    b, s_len = k.shape[0], k.shape[1]
    q_spec = spec_for(q.shape, "batch . . .", mesh)
    kv_spec = spec_for(k.shape, "batch kv_seq kv_heads head_dim", mesh)
    pos_spec = spec_for(pos.shape, "batch", mesh)
    seq_axes = kv_spec[1]
    if seq_axes is None:  # seq didn't shard after all
        return _decode_attend_local(q, k, v, pos, window, base=None)
    seq_axes = (seq_axes,) if isinstance(seq_axes, str) else tuple(seq_axes)
    n_shards = 1
    for ax in seq_axes:
        n_shards *= mesh.shape[ax]
    chunk = s_len // n_shards

    @functools.partial(
        _shard_map, mesh=mesh,
        in_specs=(q_spec, kv_spec, kv_spec, pos_spec),
        out_specs=q_spec)
    def attend(ql, kl, vl, posl):
        idx = jnp.int32(0)
        for ax in seq_axes:
            idx = idx * mesh.shape[ax] + jax.lax.axis_index(ax)
        base = idx * chunk
        o, m, l_ = _decode_attend_local(ql, kl, vl, posl, window,
                                        base=base)
        gmax = jax.lax.pmax(m, seq_axes)                 # (B,H,1)
        corr = jnp.exp(m - gmax)
        l_g = jax.lax.psum(l_ * corr, seq_axes)
        o_g = jax.lax.psum(o * corr.transpose(0, 2, 1)[..., None]
                           .astype(o.dtype), seq_axes)
        denom = jnp.maximum(l_g, 1e-30).transpose(0, 2, 1)[..., None]
        return (o_g / denom.astype(o_g.dtype)).astype(ql.dtype)

    return attend(q, k, v, pos)


def cross_attn_kv(cfg: ModelConfig, p, enc: jnp.ndarray):
    """Precompute cross-attention K/V from encoder states (prefill)."""
    cd = dtype_of(cfg.compute_dtype)
    k = jnp.einsum("bsd,dhk->bshk", enc, p["wk"].astype(cd))
    v = jnp.einsum("bsd,dhk->bshk", enc, p["wv"].astype(cd))
    return {"k": k, "v": v}


def cross_attn_decode(cfg: ModelConfig, p, x, kv):
    cd = dtype_of(cfg.compute_dtype)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(cd))
    k, v = kv["k"], kv["v"]
    h, kvh = q.shape[2], k.shape[2]
    if kvh != h:
        k = jnp.repeat(k, h // kvh, axis=2)
        v = jnp.repeat(v, h // kvh, axis=2)
    scale = 1.0 / (q.shape[-1] ** 0.5)
    s = jnp.einsum("bthk,bshk->bhts", q, k).astype(jnp.float32) * scale
    o = jnp.einsum("bhts,bshk->bthk",
                   jax.nn.softmax(s, -1).astype(v.dtype), v)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(o.dtype))


# ---------------------------------------------------------------------------
# MLA (MiniCPM3 / DeepSeek-style latent attention)
# ---------------------------------------------------------------------------


def mla_init(cfg: ModelConfig, key):
    d, h = cfg.d_model, cfg.num_heads
    qr, kr = cfg.q_lora_rank, cfg.kv_lora_rank
    nope, rope, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ks = jax.random.split(key, 5)
    dt = dtype_of(cfg.param_dtype)
    p = {
        "wq_a": _init(ks[0], (d, qr), d, dt),
        "q_norm": jnp.ones((qr,), jnp.float32),
        "wq_b": _init(ks[1], (qr, h, nope + rope), qr, dt),
        "wkv_a": _init(ks[2], (d, kr + rope), d, dt),
        "kv_norm": jnp.ones((kr,), jnp.float32),
        "wkv_b": _init(ks[3], (kr, h, nope + vd), kr, dt),
        "wo": _init(ks[4], (h, vd, d), h * vd, dt),
    }
    a = {"wq_a": "embed lora", "q_norm": "norm",
         "wq_b": "lora heads qk_dim", "wkv_a": "embed lora",
         "kv_norm": "norm", "wkv_b": "lora heads qk_dim",
         "wo": "heads head_dim embed"}
    return p, a


def _rms(x, scale):
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(jnp.square(xf), -1, keepdims=True)
                           + 1e-6) * scale
    return y.astype(x.dtype)


def _mla_qkv_latent(cfg, p, x, positions):
    cd = dtype_of(cfg.compute_dtype)
    nope, rope = cfg.qk_nope_dim, cfg.qk_rope_dim
    cq = _rms(x @ p["wq_a"].astype(cd), p["q_norm"])
    q = jnp.einsum("bsr,rhk->bshk", cq, p["wq_b"].astype(cd))
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    ckv_full = x @ p["wkv_a"].astype(cd)
    c_kv = _rms(ckv_full[..., : cfg.kv_lora_rank], p["kv_norm"])
    k_rope = ckv_full[..., cfg.kv_lora_rank:]  # (B,S,rope) shared heads
    k_rope = apply_rope(k_rope[:, :, None, :], positions,
                        cfg.rope_theta)[:, :, 0, :]
    _ = nope
    return q_nope, q_rope, c_kv, k_rope


def mla_apply(cfg: ModelConfig, p, x, *, positions, attn_impl="ref"):
    """Train/prefill MLA: materialize per-head K/V from latents."""
    cd = dtype_of(cfg.compute_dtype)
    nope, vd = cfg.qk_nope_dim, cfg.v_head_dim
    q_nope, q_rope, c_kv, k_rope = _mla_qkv_latent(cfg, p, x, positions)
    kv = jnp.einsum("bsr,rhk->bshk", c_kv, p["wkv_b"].astype(cd))
    k_nope, v = kv[..., :nope], kv[..., nope:]
    h = cfg.num_heads
    k_rope_b = jnp.broadcast_to(k_rope[:, :, None, :],
                                k_rope.shape[:2] + (h, cfg.qk_rope_dim))
    q = jnp.concatenate([q_nope, q_rope], -1)
    k = jnp.concatenate([k_nope, k_rope_b], -1)
    o = ops.attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                      v.transpose(0, 2, 1, 3), causal=True, impl=attn_impl)
    o = o.transpose(0, 2, 1, 3)
    _ = vd
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(o.dtype))


def mla_prefill(cfg: ModelConfig, p, x, *, positions, cache_len: int,
                attn_impl="ref"):
    out = mla_apply(cfg, p, x, positions=positions, attn_impl=attn_impl)
    _, _, c_kv, k_rope = _mla_qkv_latent(cfg, p, x, positions)
    s = x.shape[1]
    cache = {
        "c_kv": jnp.pad(c_kv, [(0, 0), (0, cache_len - s), (0, 0)]),
        "k_rope": jnp.pad(k_rope, [(0, 0), (0, cache_len - s), (0, 0)]),
    }
    return out, cache


def mla_decode(cfg: ModelConfig, p, x, cache, *, pos):
    """Absorbed-weight MLA decode: attention runs in the latent space —
    the KV cache holds only (kv_lora + rope) per token, the MLA win."""
    cd = dtype_of(cfg.compute_dtype)
    b = x.shape[0]
    pos_arr = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    nope, vd = cfg.qk_nope_dim, cfg.v_head_dim
    q_nope, q_rope, c_kv_new, k_rope_new = _mla_qkv_latent(
        cfg, p, x, pos_arr[:, None])
    wkv_b = p["wkv_b"].astype(cd)
    wk, wv = wkv_b[..., :nope], wkv_b[..., nope:]
    # absorb: q in latent space
    q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, wk)  # (B,1,H,kv_lora)
    s_max = cache["c_kv"].shape[1]
    onehot = (jnp.arange(s_max, dtype=jnp.int32)[None] == pos_arr[:, None])
    c_kv = cache["c_kv"] * (1 - onehot[..., None].astype(cd)) \
        + onehot[..., None].astype(cd) * c_kv_new.astype(cd)
    k_rope = cache["k_rope"] * (1 - onehot[..., None].astype(cd)) \
        + onehot[..., None].astype(cd) * k_rope_new.astype(cd)
    scale = 1.0 / ((nope + cfg.qk_rope_dim) ** 0.5)
    logits = (jnp.einsum("bthr,bsr->bhts", q_lat, c_kv)
              + jnp.einsum("bthk,bsk->bhts", q_rope, k_rope)
              ).astype(jnp.float32) * scale
    kpos = jnp.arange(s_max, dtype=jnp.int32)[None, None, None, :]
    logits = jnp.where(kpos <= pos_arr[:, None, None, None], logits,
                       -jnp.inf)
    w = jax.nn.softmax(logits, -1).astype(cd)
    ctx_lat = jnp.einsum("bhts,bsr->bthr", w, c_kv)       # latent context
    v_ctx = jnp.einsum("bthr,rhk->bthk", ctx_lat, wv)     # (B,1,H,vd)
    _ = vd
    out = jnp.einsum("bshk,hkd->bsd", v_ctx, p["wo"].astype(cd))
    return out, {"c_kv": c_kv, "k_rope": k_rope}


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def mlp_init(cfg: ModelConfig, key, d_ff: Optional[int] = None):
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    dt = dtype_of(cfg.param_dtype)
    if cfg.mlp_kind == "swiglu":
        ks = jax.random.split(key, 3)
        p = {"wi": _init(ks[0], (d, ff), d, dt),
             "wg": _init(ks[1], (d, ff), d, dt),
             "wo": _init(ks[2], (ff, d), ff, dt)}
        a = {"wi": "embed mlp", "wg": "embed mlp", "wo": "mlp embed"}
    else:
        ks = jax.random.split(key, 2)
        p = {"wi": _init(ks[0], (d, ff), d, dt),
             "wo": _init(ks[1], (ff, d), ff, dt)}
        a = {"wi": "embed mlp", "wo": "mlp embed"}
    return p, a


def mlp_apply(cfg: ModelConfig, p, x):
    cd = dtype_of(cfg.compute_dtype)
    h = x @ p["wi"].astype(cd)
    if cfg.mlp_kind == "swiglu":
        g = x @ p["wg"].astype(cd)
        h = jax.nn.silu(g) * h
    elif cfg.mlp_kind == "squared_relu":
        h = jnp.square(jax.nn.relu(h))
    else:  # gelu
        h = jax.nn.gelu(h)
    return h @ p["wo"].astype(cd)


__all__ = [k for k in dir() if not k.startswith("_")]
_ = (dataclasses, Tuple, constrain)
