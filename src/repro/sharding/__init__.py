from .rules import (DEFAULT_RULES, constrain, param_shardings, spec_for,
                    tree_spec)  # noqa: F401
