"""Logical-axis sharding rules (MaxText-style).

Every parameter / activation carries a tuple of *logical* axis names; a
rule table maps logical → mesh axes.  ``spec_for`` drops mesh axes that
are absent from the mesh (so the same model code runs on a single device,
a (data, model) pod slice, or a (pod, data, model) multi-pod mesh) and
refuses shardings that don't divide the dimension (falls back to
replication for that dim rather than relying on padding).

Default layout = FSDP × TP:
  batch        → (pod, data)     activations
  embed        → data            parameter d_model dim (ZeRO-3 style)
  heads/mlp/vocab/expert → model tensor parallelism
  kv_seq       → model           decode KV cache (flash-decoding style;
                                 GQA kv_heads < |model| so we shard time)
"""

from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> mesh axes (tried in order; tuple = shard over several)
# "embed"-class axes are GREEDY-FILL: resolved in a second pass so they
# soak up whatever mesh axes the structured dims (heads/kv/mlp/vocab)
# could not use — e.g. GQA kv_heads (1–8) never divides model=16, so
# wk/wv would otherwise replicate 16× on the model axis (1.4 GB/chip at
# 340B scale).
_GREEDY = ("embed", "embed2")
# "model2" entries are inert on the standard (data, model) mesh and give
# the factored mesh (data, model=8, model2=2) full coverage: heads that
# divide 8 but not 16 shard over "model", while mlp/vocab/... take both.
DEFAULT_RULES: Dict[str, Tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "embed": ("data", "model", "model2"),
    "embed2": ("data", "model", "model2"),
    # kv projections keep embed on data ONLY: 2-D-sharding them fights the
    # sharding GSPMD propagates from the attention einsums (kv_heads on a
    # model sub-axis) and triggers "involuntary full rematerialization" —
    # 2×10 GiB f32 all-gathers of the stacked kv weights at 340B scale.
    "embed_kv": ("data",),
    "heads": ("model", "model2"),
    "kv_heads": ("model", "model2"),
    "mlp": ("model", "model2"),
    "vocab": ("model", "model2"),
    "expert": ("model", "model2"),
    "kv_seq": ("model", "model2"),
    "seq": (),
    "seq_model": ("model", "model2"),  # sequence-parallel boundary
    "head_dim": (),
    "qk_dim": (),
    "state": (),
    "layers": (),
    "conv": (),
    "lora": (),
    "capacity": (),
    "enc_seq": (),
    "img_seq": (),
    "stack": (),
    "norm": (),
}


def parse_axes(axes) -> Tuple[Optional[str], ...]:
    """Axes are spelled as a space-separated string so they are pytree
    LEAVES (tuples would be treated as nodes by jax.tree.map).  '.' = None.
    e.g. "embed heads head_dim"."""
    if isinstance(axes, str):
        return tuple(None if a == "." else a for a in axes.split())
    return tuple(axes)


def spec_for(shape: Sequence[int], axes, mesh: Mesh,
             rules: Optional[Dict] = None) -> P:
    """Build a PartitionSpec for ``shape`` whose dims are named ``axes``.

    Two-phase: structured dims first (heads/mlp/vocab/...), then the
    greedy-fill dims ("embed") claim any mesh axes still unused — so a
    kv_heads=8 weight still ends up 256-way sharded via its embed dim."""
    rules = rules or DEFAULT_RULES
    axes = parse_axes(axes)
    assert len(shape) == len(axes), (shape, axes)
    used: set = set()
    parts: list = [None] * len(shape)

    def assign(i, dim, name):
        mesh_axes = rules.get(name, ())
        picked = []
        extent = 1
        for ax in mesh_axes:
            if ax in mesh.shape and ax not in used:
                if dim % (extent * mesh.shape[ax]) == 0:
                    picked.append(ax)
                    extent *= mesh.shape[ax]
                    used.add(ax)
        if picked:
            parts[i] = tuple(picked) if len(picked) > 1 else picked[0]

    for i, (dim, name) in enumerate(zip(shape, axes)):
        if name is not None and name not in _GREEDY:
            assign(i, dim, name)
    for i, (dim, name) in enumerate(zip(shape, axes)):
        if name in _GREEDY:
            assign(i, dim, name)
    return P(*parts)


def tree_spec(params, param_axes, mesh: Mesh,
              rules: Optional[Dict] = None):
    """Map a (params, axes-string) pytree pair to a PartitionSpec pytree."""
    return jax.tree.map(
        lambda p, a: spec_for(np.shape(p), a, mesh, rules),
        params, param_axes)


def param_shardings(params, param_axes, mesh: Mesh,
                    rules: Optional[Dict] = None):
    specs = tree_spec(params, param_axes, mesh, rules)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def _current_mesh() -> Optional[Mesh]:
    try:
        env = jax.interpreters.pxla.thread_resources.env
        m = env.physical_mesh
        if m.empty:
            return None
        return m
    except Exception:
        return None


def constrain(x, axes: Sequence[Optional[str]],
              rules: Optional[Dict] = None):
    """Best-effort activation sharding constraint.  No-op without a mesh
    context (single-device tests)."""
    mesh = _current_mesh()
    if mesh is None:
        return x
    spec = spec_for(x.shape, axes, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
