"""Public session + serving API — ``repro.api``.

Prepare-once / query-many graph processing (see ``core/api.py``):

    from repro import api
    proc = api.GraphProcessor(g, b=16, num_clusters=64)
    pr = proc.pagerank()
    d = proc.sssp(sources=[0, 5, 9])          # batched, one compile
    fast = api.ExecutionPolicy(mode="async", kernel=api.KernelSpec(
        impl="pallas", fuse_frontier=True, autotune=True))
    d2 = proc.sssp(0, policy=fast)

Serving many graphs (see ``serve/graph.py``): a ``GraphService`` holds a
named graph registry, a shared byte-bounded LRU plan store with an
on-disk persistence tier (warm restarts skip the compile pipeline), and
a ``submit``/``gather`` front door that coalesces same-plan
single-source queries into batched runs:

    svc = api.GraphService(cache_dir=".plan-cache")
    svc.register("roads", g, b=16, num_clusters=64)
    t = svc.submit("roads", api.QuerySpec(algo="sssp", sources=(0,)))
    dist = svc.gather()[t].values

Serving many *clients* (see ``serve/server.py``): a ``GraphServer``
accepts concurrent ``submit(...) → Future`` requests and a background
wave scheduler closes batched waves across clients (continuous
batching), with per-request deadlines, ``Backpressure`` admission
control, and background plan warming from the store's access log:

    server = api.GraphServer(cache_dir=".plan-cache")
    server.register("roads", g, b=16, num_clusters=64)
    fut = server.submit("roads",
                        api.QuerySpec(algo="sssp", sources=(0,)),
                        deadline=0.5)
    dist = fut.result().values
"""

from .core.algorithms import (AlgorithmSpec, get_algorithm,  # noqa: F401
                              register_algorithm,
                              registered_algorithms)
from .core.api import (ExecutionPolicy, GraphProcessor, PlanKey,  # noqa: F401
                       QuerySpec, Result, degrade_policy)
from .core.engine import (PlanIntegrityError, Prepared,  # noqa: F401
                          RunStats, deserialize_prepared,
                          serialize_prepared)
from .core.placement import DistStats  # noqa: F401
from .kernels.spec import KernelSpec  # noqa: F401
from .resilience import (FaultInjected, FaultPlan, FaultSpec,  # noqa: F401
                         inject, is_transient)
from .serve.graph import GraphService, PlanStore  # noqa: F401
from .serve.sched import (Backpressure, DeadlineExceeded,  # noqa: F401
                          ServerClosed, WavePolicy, WaveScheduler,
                          WaveTimeout)
from .serve.server import GraphServer  # noqa: F401

__all__ = ["AlgorithmSpec", "ExecutionPolicy", "GraphProcessor",
           "GraphService", "KernelSpec", "PlanKey", "PlanStore",
           "QuerySpec", "Result", "Prepared", "RunStats", "DistStats",
           "serialize_prepared", "deserialize_prepared", "GraphServer",
           "WaveScheduler", "WavePolicy", "DeadlineExceeded",
           "Backpressure", "ServerClosed", "WaveTimeout",
           "PlanIntegrityError", "degrade_policy", "FaultPlan",
           "FaultSpec", "FaultInjected", "inject", "is_transient",
           "get_algorithm", "register_algorithm",
           "registered_algorithms"]
