"""Public session API — ``repro.api``.

Prepare-once / query-many graph processing (see ``core/api.py``):

    from repro import api
    proc = api.GraphProcessor(g, b=16, num_clusters=64)
    pr = proc.pagerank()
    d = proc.sssp(sources=[0, 5, 9])          # batched, one compile
    fast = api.ExecutionPolicy(mode="async", impl="pallas")
    d2 = proc.sssp(0, policy=fast)
"""

from .core.api import (ExecutionPolicy, GraphProcessor, PlanKey,  # noqa: F401
                       QuerySpec, Result)
from .core.engine import Prepared, RunStats  # noqa: F401

__all__ = ["ExecutionPolicy", "GraphProcessor", "PlanKey", "QuerySpec",
           "Result", "Prepared", "RunStats"]
