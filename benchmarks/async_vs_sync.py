"""MEASURED comparison of the paper's asynchronous model vs bulk-
synchronous execution: sweeps, edge work, and clustering effect — the
reproduction's directly-verifiable core claim (no hardware model)."""

from __future__ import annotations

from repro.core import algorithms as A
from repro.core import cluster as C
from repro.core import graph as G

from . import common


def run(graphs=None, emit=common.csv_line):
    graphs = graphs or common.load_graphs()
    rows = []
    for gname, g in graphs.items():
        for algo in ("sssp", "bfs", "pagerank", "cc"):
            ra, wa = common.run_algo(g, algo, "async")
            rs, ws = common.run_algo(g, algo, "sync")
            work_ratio = rs.stats.edge_work / max(ra.stats.edge_work, 1)
            emit(f"async_vs_sync/{gname}/{algo}", wa * 1e6,
                 f"async_sweeps={ra.stats.sweeps} "
                 f"sync_sweeps={rs.stats.sweeps} "
                 f"work_reduction={work_ratio:.2f}x")
            rows.append(dict(graph=gname, algo=algo,
                             async_sweeps=ra.stats.sweeps,
                             sync_sweeps=rs.stats.sweeps,
                             async_edge_work=ra.stats.edge_work,
                             sync_edge_work=rs.stats.edge_work,
                             work_reduction=work_ratio,
                             wall_async_s=wa, wall_sync_s=ws))
    # clustering quality (compile-time step the speedups rest on).
    # Real graphs arrive with ARBITRARY vertex ids — measure how much
    # locality clustering recovers from a randomly-relabeled graph
    # (identity order of a synthetic generator is unrealistically good).
    import numpy as np
    for gname, g in graphs.items():
        rng = np.random.default_rng(0)
        shuffled = g.permute(
            rng.permutation(g.n).astype(np.int32))
        c = C.cluster_graph(shuffled, 64)
        st = C.tile_stats_after(shuffled, c, b=16)
        emit(f"clustering/{gname}", 0.0,
             f"fill: shuffled={st['fill_identity']:.4f} → "
             f"clustered={st['fill_clustered']:.4f} "
             f"({st['tile_reduction']:.2f}x fewer tiles); "
             f"cut={c.cut_fraction:.3f}")
        rows.append(dict(graph=gname, cut=c.cut_fraction, **st))
    _ = G
    return rows
