"""Paper Fig. 6 — power per platform × graph × algorithm (modeled energy
over modeled time; constants documented in core/power.py)."""

from __future__ import annotations

from . import common


def run(graphs=None, emit=common.csv_line):
    graphs = graphs or common.load_graphs()
    rows = []
    for gname, g in graphs.items():
        for algo in common.ALGOS:
            rep = common.platform_reports(g, algo)
            nale, cpu, gpu = rep["nale"], rep["cpu"], rep["gpu"]
            eff_gpu = (nale.perf_per_watt
                       / max(gpu.perf_per_watt, 1e-12))
            emit(f"fig6/{gname}/{algo}/power_w", 0.0,
                 f"nale={nale.power_w:.2f} cpu={cpu.power_w:.2f} "
                 f"gpu={gpu.power_w:.2f}")
            emit(f"fig6/{gname}/{algo}/perfW_vs_gpu", 0.0,
                 f"{eff_gpu:.1f}x")
            rows.append(dict(graph=gname, algo=algo,
                             nale_w=nale.power_w, cpu_w=cpu.power_w,
                             gpu_w=gpu.power_w,
                             nale_j=nale.energy_j, cpu_j=cpu.energy_j,
                             gpu_j=gpu.energy_j,
                             perf_per_watt_vs_gpu=eff_gpu))
    return rows
