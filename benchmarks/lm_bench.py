"""LM substrate microbench: reduced-config train-step and decode-step
wall clock on CPU (harness completeness; real perf numbers come from the
dry-run roofline)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import lm
from repro.train.optimizer import AdamW, warmup_cosine
from repro.train.step import make_train_step

from . import common


def run(graphs=None, emit=common.csv_line):
    rows = []
    for arch in ("granite-3-2b", "rwkv6-1.6b", "dbrx-132b"):
        cfg = get_config(arch).reduced()
        params, _ = lm.init(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        b, s = 4, 128
        batch = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
            "labels": jnp.asarray(
                rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
            "loss_mask": jnp.ones((b, s), jnp.float32)}
        opt = AdamW(lr=warmup_cosine(1e-3, 2, 100))
        step = jax.jit(make_train_step(cfg, opt))
        st = opt.init(params)
        p, st, m = step(params, st, batch)
        jax.block_until_ready(m["loss"])
        t0 = time.time()
        n = 5
        for _ in range(n):
            p, st, m = step(p, st, batch)
        jax.block_until_ready(m["loss"])
        dt = (time.time() - t0) / n
        tput = b * s / dt
        emit(f"lm/train_step/{arch}", dt * 1e6,
             f"tokens_per_s={tput:.0f}")
        rows.append(dict(arch=arch, what="train", us=dt * 1e6,
                         tokens_per_s=tput))

        logits, cache = jax.jit(lambda pp, bt: lm.prefill(
            cfg, pp, bt, cache_len=s + 16))(
                p, {k: v for k, v in batch.items() if k == "tokens"})
        dstep = jax.jit(lambda pp, c, t, pos: lm.decode_step(
            cfg, pp, c, t, pos))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        lg, cache = dstep(p, cache, tok, jnp.int32(s))
        jax.block_until_ready(lg)
        t0 = time.time()
        for i in range(8):
            lg, cache = dstep(p, cache, tok, jnp.int32(s + 1 + i))
        jax.block_until_ready(lg)
        dt = (time.time() - t0) / 8
        emit(f"lm/decode_step/{arch}", dt * 1e6,
             f"tokens_per_s={b/dt:.0f}")
        rows.append(dict(arch=arch, what="decode", us=dt * 1e6,
                         tokens_per_s=b / dt))
    _ = common
    return rows
