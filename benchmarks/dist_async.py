"""``dist_async`` sweep family: the self-timed asynchronous distributed
engine (k local sweeps per halo exchange, ``core/async_dist.py``) vs the
bulk-synchronous one.

Both flavors converge to bit-identical values; what k > 1 buys is
COLLECTIVES — ``DistStats.halo_exchanges`` drops from one-per-sweep
toward one-per-k-sweeps — at the price of some extra (overlappable)
local sweeps.  The speedup is MODELED like the other families so the
trend gate stays deterministic: per-sweep NALE compute time from the
measured work counters, plus a reference interconnect charge per halo
exchange (bytes / bandwidth + latency, constants below — a commodity
25 GbE-class node, the regime the paper's self-timed argument targets).
The sync engine pays ``sweeps × (compute + exchange)``; the async engine
pays its straggler's local sweeps of compute but only ``halo_exchanges``
exchange charges.
"""

from __future__ import annotations

import numpy as np

from repro.core import engine as eng
from repro.core import power as PW

from . import common

QUERIES = 4          # sources per batch
KS = (2, 4)          # local sweeps per exchange
NET_BYTES_PER_S = 3e9   # reference interconnect bandwidth (~25 GbE)
NET_LATENCY_S = 20e-6   # per-collective launch + rendezvous latency
REF_GRAPH_SHARDS = 8    # modeled "graph" extent for the halo volume


def _exchange_time_s(dist) -> float:
    """Modeled wall time of ONE tiled halo all_gather on the reference
    node: per-device payload over the wire plus collective latency."""
    payload = dist.halo_bytes_per_sweep * max(REF_GRAPH_SHARDS /
                                              max(dist.mesh_shape[0], 1),
                                              1.0)
    return payload / NET_BYTES_PER_S + NET_LATENCY_S


def run(graphs=None, emit=common.csv_line):
    graphs = graphs or common.load_graphs()
    rows = []
    for gname, g in graphs.items():
        sources = [int(s) for s in
                   np.linspace(0, g.n - 1, QUERIES, dtype=np.int64)]
        for algo in ("sssp", "bfs"):
            rs, wall_s = common.run_batched(g, algo, sources)
            ds_sync = rs.extra["dist"]
            p = rs.prepared
            t_sweep = PW.model_nale(
                p, eng.bsp_stats(p, 1, True, "distributed")).time_s
            t_exch = _exchange_time_s(ds_sync)
            # BSP: every sweep pays compute + a blocking exchange
            sync_s = ds_sync.sweeps * (t_sweep + t_exch)
            for k in KS:
                ra, wall_a = common.run_batched(
                    g, algo, sources, dist_flavor="async",
                    local_sweeps=k)
                ds = ra.extra["dist"]
                assert np.array_equal(np.asarray(ra.values),
                                      np.asarray(rs.values)), \
                    f"async flavor diverged on {gname}/{algo} k={k}"
                # self-timed: straggler-bound local compute + one
                # exchange charge per round (the double-buffered gather
                # overlaps interior compute; charging it fully keeps the
                # model conservative)
                async_s = ds.sweeps * t_sweep \
                    + ds.halo_exchanges * t_exch
                speedup = sync_s / max(async_s, 1e-12)
                halo_red = ds_sync.halo_exchanges / max(
                    ds.halo_exchanges, 1)
                emit(f"dist_async/{gname}/{algo}/k{k}", wall_a * 1e6,
                     f"exchanges={ds_sync.halo_exchanges}->"
                     f"{ds.halo_exchanges} ({halo_red:.2f}x) "
                     f"sweeps={ds_sync.sweeps}->{ds.sweeps} "
                     f"modeled_speedup={speedup:.2f}x")
                rows.append(dict(
                    graph=gname, algo=algo, k=k, queries=len(sources),
                    sweeps_sync=ds_sync.sweeps, sweeps_async=ds.sweeps,
                    exchanges_sync=ds_sync.halo_exchanges,
                    exchanges_async=ds.halo_exchanges,
                    halo_exchange_reduction=halo_red,
                    shard_sweeps=[int(s) for s in ds.shard_sweeps],
                    halo_bytes_per_exchange=ds.halo_bytes_per_sweep,
                    speedup_vs_sync=speedup,
                    wall_async_s=wall_a, wall_sync_s=wall_s))
    return rows
