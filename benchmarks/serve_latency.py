"""``serve_latency`` sweep family: the continuous-batching front door
under concurrent clients — request latency (p50/p99), achieved wave
batch size, and a MODELED batching speedup the trend gate protects.

Client threads submit single-source SSSP/BFS requests into a paused
``GraphServer`` (so the wave composition — hence everything the gate
reads — is deterministic); starting the scheduler then closes full
``max_wave``-sized waves.  Wall-clock p50/p99 (submit → future done)
and the achieved wave size are reported for operators; the *gated*
number is modeled exactly like ``dist_batched``: per-request NALE
critical paths from the measured solo sweep counts executed
back-to-back (unbatched front door) vs straggler-bound waves (what the
scheduler dispatched), which depends only on engine work counters.
"""

from __future__ import annotations

import threading
import time

import numpy as np

from repro import api
from repro.core import power as PW

from . import common

QUERIES = 8        # requests per (graph, algo) load burst
CLIENTS = 4        # submitting threads
MAX_WAVE = 4       # scheduler wave size → QUERIES/MAX_WAVE full waves


def _burst(server, name, algo, sources):
    """Submit QUERIES requests from CLIENTS threads; returns
    ({src: future}, {src: t_submit}, {src: t_done})."""
    futs, t_sub, t_done = {}, {}, {}
    lock = threading.Lock()
    barrier = threading.Barrier(CLIENTS)

    def client(chunk):
        barrier.wait()
        for s in chunk:
            t0 = time.perf_counter()
            f = server.submit(name, api.QuerySpec(algo=algo,
                                                  sources=(s,)))
            f.add_done_callback(
                lambda _f, s=s: t_done.__setitem__(
                    s, time.perf_counter()))
            with lock:
                futs[s], t_sub[s] = f, t0

    threads = [threading.Thread(target=client, args=(sources[i::CLIENTS],))
               for i in range(CLIENTS)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return futs, t_sub, t_done


def run(graphs=None, emit=common.csv_line):
    graphs = graphs or common.load_graphs()
    svc = common.service()
    rows = []
    for gname, g in graphs.items():
        name = common.register_name(g)
        common.processor(g)   # ensure registered (idempotent)
        sources = [int(s) for s in
                   np.linspace(0, g.n - 1, QUERIES, dtype=np.int64)]
        for algo in ("sssp", "bfs"):
            # solo runs first: the bit-identity reference AND the
            # per-request sweep counts the sequential model needs
            solo = {s: svc.run(name, api.QuerySpec(algo=algo,
                                                   sources=(s,)))
                    for s in sources}
            server = api.GraphServer(
                service=svc, autostart=False,
                wave=api.WavePolicy(max_wave=MAX_WAVE, max_wait_s=0.5))
            futs, t_sub, t_done = _burst(server, name, algo, sources)
            server.start()
            results = {s: f.result(timeout=600)
                       for s, f in futs.items()}
            sched = server.stats()["scheduler"]
            server.close()
            for s in sources:   # serving must never change answers
                if not np.array_equal(results[s].values,
                                      solo[s].values):
                    raise AssertionError(
                        f"wave result diverged from direct run "
                        f"({gname}/{algo} src={s})")
            lat = np.array([t_done[s] - t_sub[s] for s in sources])
            p50, p99 = np.percentile(lat, [50, 99])
            # modeled: Q solo dispatches back-to-back vs straggler-
            # bound waves of MAX_WAVE.  The reference wave composition
            # is source-order chunks — NOT whatever the threads' race
            # produced — so the number depends only on engine work
            # counters (deterministic for a scale/seed), like
            # dist_batched's reference node
            p = results[sources[0]].prepared
            times = [PW.model_nale(p, solo[s].stats).time_s
                     for s in sources]
            seq_s = sum(times)
            bat_s = sum(max(times[i:i + MAX_WAVE])
                        for i in range(0, len(times), MAX_WAVE))
            speedup = seq_s / max(bat_s, 1e-12)
            emit(f"serve/{gname}/{algo}", p50 * 1e6,
                 f"Q={QUERIES} clients={CLIENTS} "
                 f"waves={sched['waves']} "
                 f"wave={sched['achieved_wave']:.1f} "
                 f"p99_ms={p99 * 1e3:.1f} "
                 f"modeled_speedup={speedup:.2f}x")
            rows.append(dict(
                graph=gname, algo=algo, queries=QUERIES,
                clients=CLIENTS, max_wave=MAX_WAVE,
                waves=int(sched["waves"]),
                achieved_wave=float(sched["achieved_wave"]),
                expired=int(sched["expired"]),
                p50_ms=float(p50 * 1e3), p99_ms=float(p99 * 1e3),
                speedup_vs_unbatched=float(speedup)))
    return rows
