"""Paper Fig. 5 — execution time (cycles) per platform × graph ×
algorithm, on statistically matched stand-in graphs (offline container;
see DESIGN.md §2 assumption 3).  The NALE/CPU/GPU numbers are MODELED
cycles from the analytical models in core/power.py, driven by the work
counters the engines MEASURE."""

from __future__ import annotations

from . import common


def run(graphs=None, emit=common.csv_line):
    graphs = graphs or common.load_graphs()
    rows = []
    for gname, g in graphs.items():
        for algo in common.ALGOS:
            rep = common.platform_reports(g, algo)
            nale, cpu, gpu = rep["nale"], rep["cpu"], rep["gpu"]
            speedup_cpu = cpu.time_s / max(nale.time_s, 1e-12)
            speedup_gpu = gpu.time_s / max(nale.time_s, 1e-12)
            emit(f"fig5/{gname}/{algo}/nale_cycles",
                 rep["wall_async"] * 1e6,
                 f"cycles={nale.cycles:.3g}")
            emit(f"fig5/{gname}/{algo}/cpu_cycles", 0.0,
                 f"cycles={cpu.cycles:.3g}")
            emit(f"fig5/{gname}/{algo}/gpu_cycles", 0.0,
                 f"cycles={gpu.cycles:.3g}")
            emit(f"fig5/{gname}/{algo}/speedup", 0.0,
                 f"vs_cpu={speedup_cpu:.1f}x vs_gpu={speedup_gpu:.1f}x")
            rows.append(dict(graph=gname, algo=algo,
                             nale_cycles=nale.cycles,
                             cpu_cycles=cpu.cycles,
                             gpu_cycles=gpu.cycles,
                             speedup_cpu=speedup_cpu,
                             speedup_gpu=speedup_gpu,
                             sweeps_async=rep["async_stats"].sweeps,
                             sweeps_sync=rep["sync_stats"].sweeps,
                             edge_work_async=rep["async_stats"].edge_work,
                             edge_work_sync=rep["sync_stats"].edge_work,
                             crit_tiles_async=rep["async_stats"].crit_tiles,
                             crit_tiles_sync=rep["sync_stats"].crit_tiles))
    return rows
