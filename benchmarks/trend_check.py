"""Bench-trend gate: diff a fresh ``BENCH_graph.json`` against the
committed snapshot and fail CI on a modeled-speedup regression.

The modeled NALE-vs-CPU speedups (fig5) are deterministic for a given
scale/seed, so any drift is a real change in engine work counters or the
compile pipeline — exactly what a perf-regression gate should catch.

  python -m benchmarks.trend_check BASELINE FRESH [--threshold 0.25]

Exits non-zero when the geomean modeled speedup over the (graph, algo)
pairs present in both snapshots regresses by more than ``threshold``
(default 25%).  Also reports per-entry drift and the fresh run's
plan-store hit rate.
"""

from __future__ import annotations

import argparse
import json
import sys

import numpy as np


def _fig5_speedups(snapshot: dict) -> dict:
    return {(r["graph"], r["algo"]): float(r["speedup_cpu"])
            for r in snapshot.get("fig5", [])
            if r.get("speedup_cpu") is not None}


def compare(baseline: dict, fresh: dict, threshold: float) -> int:
    base = _fig5_speedups(baseline)
    new = _fig5_speedups(fresh)
    if not base:
        # nothing to gate against (e.g. baseline was taken with fig5
        # skipped) — the only case where passing vacuously is right
        print("trend: baseline snapshot has no fig5 entries — "
              "skipping gate")
        return 0
    missing = sorted(set(base) - set(new))
    if missing:
        # a baseline entry vanishing from the fresh run is itself a
        # regression (broken emission, renamed keys, dropped algo) —
        # never let it silently shrink the comparison
        print(f"trend: FAIL — {len(missing)} baseline entries missing "
              f"from the fresh snapshot: {missing}")
        return 1
    shared = sorted(base)
    ratios = []
    for k in shared:
        ratio = max(new[k], 1e-12) / max(base[k], 1e-12)
        ratios.append(ratio)
        flag = "  << regressed" if ratio < 1.0 - threshold else ""
        print(f"trend: {k[0]:>4s}/{k[1]:<9s} speedup "
              f"{base[k]:9.2f} -> {new[k]:9.2f}  ({ratio:6.3f}x){flag}")
    geo = float(np.exp(np.log(ratios).mean()))
    print(f"trend: geomean modeled-speedup ratio {geo:.3f}x over "
          f"{len(shared)} entries (gate: >{1.0 - threshold:.2f})")
    store = fresh.get("plan_store")
    if store:
        print(f"trend: plan-store hit rate {store['hit_rate']:.1%} "
              f"({store['plans']} plans, {store['misses']} builds)")
    if geo < 1.0 - threshold:
        print(f"trend: FAIL — modeled speedup regressed "
              f"{(1.0 - geo):.1%} (> {threshold:.0%} budget)")
        return 1
    print("trend: OK")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline", help="committed snapshot (BENCH_graph.json)")
    ap.add_argument("fresh", help="snapshot from this run")
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="max tolerated geomean speedup regression")
    args = ap.parse_args()
    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.fresh) as f:
        fresh = json.load(f)
    if baseline.get("meta", {}).get("scale") != \
            fresh.get("meta", {}).get("scale"):
        print("trend: WARNING — snapshots were taken at different scales; "
              "ratios may not be meaningful")
    return compare(baseline, fresh, args.threshold)


if __name__ == "__main__":
    sys.exit(main())
