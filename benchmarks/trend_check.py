"""Bench-trend gate: diff a fresh ``BENCH_graph.json`` against the
committed snapshot and fail CI on a modeled-speedup regression.

The modeled speedups (fig5's NALE-vs-CPU, distributed_batched's
batch-vs-sequential dispatch) are deterministic for a given scale/seed,
so any drift is a real change in engine work counters or the compile
pipeline — exactly what a perf-regression gate should catch.

  python -m benchmarks.trend_check BASELINE FRESH [--threshold 0.25]

Each gated sweep family is compared independently: exits non-zero when a
family's geomean modeled speedup over the entries present in both
snapshots regresses by more than ``threshold`` (default 25%), or when a
baseline entry vanishes from a family both snapshots carry.  A family
present in only ONE snapshot (e.g. the baseline predates the family, or
a lane skipped it) is skipped with a warning instead of failing — new
sweep families must not require lock-step snapshot refreshes to land.
Also reports per-entry drift and the fresh run's plan-store hit rate.
"""

from __future__ import annotations

import argparse
import json
import sys

import numpy as np


def _fig5_speedups(snapshot: dict) -> dict:
    return {(r["graph"], r["algo"]): float(r["speedup_cpu"])
            for r in snapshot.get("fig5", [])
            if r.get("speedup_cpu") is not None}


def _dist_batched_speedups(snapshot: dict) -> dict:
    return {(r["graph"], r["algo"]): float(r["speedup_vs_sequential"])
            for r in snapshot.get("distributed_batched", [])
            if r.get("speedup_vs_sequential") is not None}


def _dist_async_speedups(snapshot: dict) -> dict:
    # gates the self-timed engine's modeled advantage over the
    # bulk-synchronous flavor, per (graph, algo, k) — a regression here
    # means the exchange schedule got chattier or sweeps ballooned
    return {(r["graph"], r["algo"], f"k{r['k']}"):
            float(r["speedup_vs_sync"])
            for r in snapshot.get("dist_async", [])
            if r.get("speedup_vs_sync") is not None}


def _kernel_fused_speedups(snapshot: dict) -> dict:
    # gates the fused kernel's modeled advantage over the unfused sync
    # loop (active-tile skipping + 3-launches-to-1 fusion); tile_work
    # comes from engine counters, so drift means the frontier trajectory
    # or the skipping itself changed
    return {(r["graph"], r["algo"]): float(r["speedup_modeled"])
            for r in snapshot.get("kernel_fused", [])
            if r.get("speedup_modeled") is not None}


def _algo_suite_speedups(snapshot: dict) -> dict:
    # gates the algorithm catalog (pagerank_delta / cc / kcore /
    # tricount) on the same NALE-vs-CPU modeled speedup as fig5 — drift
    # means an update rule's sweep/edge-work trajectory changed
    return {(r["graph"], r["algo"]): float(r["speedup_cpu"])
            for r in snapshot.get("algo_suite", [])
            if r.get("speedup_cpu") is not None}


def _serve_latency_speedups(snapshot: dict) -> dict:
    # the family's wall p50/p99 are operator info (host-dependent); the
    # gated number is the modeled batching speedup, which depends only
    # on engine work counters and the reference wave composition
    return {(r["graph"], r["algo"]): float(r["speedup_vs_unbatched"])
            for r in snapshot.get("serve_latency", [])
            if r.get("speedup_vs_unbatched") is not None}


# family name -> extractor of {entry_key: modeled_speedup}
FAMILIES = {
    "fig5": _fig5_speedups,
    "distributed_batched": _dist_batched_speedups,
    "dist_async": _dist_async_speedups,
    "kernel_fused": _kernel_fused_speedups,
    "serve_latency": _serve_latency_speedups,
    "algo_suite": _algo_suite_speedups,
}


def _compare_family(family: str, base: dict, new: dict,
                    threshold: float) -> int:
    missing = sorted(set(base) - set(new))
    if missing:
        # a baseline entry vanishing from a family BOTH snapshots carry
        # is itself a regression (broken emission, renamed keys, dropped
        # algo) — never let it silently shrink the comparison
        print(f"trend: FAIL — {family}: {len(missing)} baseline entries "
              f"missing from the fresh snapshot: {missing}")
        return 1
    shared = sorted(base)
    ratios = []
    for k in shared:
        ratio = max(new[k], 1e-12) / max(base[k], 1e-12)
        ratios.append(ratio)
        flag = "  << regressed" if ratio < 1.0 - threshold else ""
        name = "/".join(str(part) for part in k)
        print(f"trend: {family}/{name:<14s} speedup "
              f"{base[k]:9.2f} -> {new[k]:9.2f}  ({ratio:6.3f}x){flag}")
    geo = float(np.exp(np.log(ratios).mean()))
    print(f"trend: {family}: geomean modeled-speedup ratio {geo:.3f}x "
          f"over {len(shared)} entries (gate: >{1.0 - threshold:.2f})")
    if geo < 1.0 - threshold:
        print(f"trend: FAIL — {family}: modeled speedup regressed "
              f"{(1.0 - geo):.1%} (> {threshold:.0%} budget)")
        return 1
    return 0


def compare(baseline: dict, fresh: dict, threshold: float) -> int:
    rc = 0
    gated = 0
    for family, extract in FAMILIES.items():
        base = extract(baseline)
        new = extract(fresh)
        if not base and not new:
            continue
        if not base or not new:
            only_in = "fresh" if not base else "baseline"
            print(f"trend: WARNING — family {family!r} present only in "
                  f"the {only_in} snapshot — skipping it (refresh the "
                  "committed snapshot to start gating it)")
            continue
        gated += 1
        rc = max(rc, _compare_family(family, base, new, threshold))
    if not gated:
        # nothing to gate against (e.g. baseline was taken with every
        # family skipped) — the only case where passing vacuously is
        # right
        print("trend: no sweep family present in both snapshots — "
              "skipping gate")
        return rc
    store = fresh.get("plan_store")
    if store:
        tiers = ""
        if "mem_hit_rate" in store:   # older snapshots lack the split
            tiers = (f" = {store['mem_hit_rate']:.1%} mem "
                     f"+ {store['disk_hit_rate']:.1%} disk")
        print(f"trend: plan-store hit rate {store['hit_rate']:.1%}"
              f"{tiers} ({store['plans']} plans, {store['misses']} "
              "builds)")
    if rc == 0:
        print("trend: OK")
    return rc


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline", help="committed snapshot (BENCH_graph.json)")
    ap.add_argument("fresh", help="snapshot from this run")
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="max tolerated geomean speedup regression")
    args = ap.parse_args()
    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.fresh) as f:
        fresh = json.load(f)
    if baseline.get("meta", {}).get("scale") != \
            fresh.get("meta", {}).get("scale"):
        print("trend: WARNING — snapshots were taken at different scales; "
              "ratios may not be meaningful")
    return compare(baseline, fresh, args.threshold)


if __name__ == "__main__":
    sys.exit(main())
