"""Shared benchmark plumbing: paper workloads at configurable scale,
platform models, CSV emission."""

from __future__ import annotations

import os
import time

import numpy as np

from repro.core import algorithms as A
from repro.core import graph as G
from repro.core import power as PW

SCALE = float(os.environ.get("REPRO_BENCH_SCALE", 1.0 / 256))

ALGOS = ["sssp", "bfs", "pagerank", "cc", "minitri", "dfs"]
GRAPH_NAMES = ["ca", "fb", "lj"]


def load_graphs(scale: float = SCALE):
    return {name: G.make_paper_graph(name, scale=scale, seed=7)
            for name in GRAPH_NAMES}


def run_algo(g, algo: str, mode: str, b: int = 16, num_clusters: int = 64):
    t0 = time.time()
    if algo == "sssp":
        r = A.sssp(g, 0, mode=mode, b=b, num_clusters=num_clusters)
    elif algo == "bfs":
        r = A.bfs(g, 0, mode=mode, b=b, num_clusters=num_clusters)
    elif algo == "pagerank":
        r = A.pagerank(g, tol=1e-7, mode=mode, b=b,
                       num_clusters=num_clusters)
    elif algo == "cc":
        r = A.connected_components(g, mode=mode, b=b,
                                   num_clusters=num_clusters)
    elif algo == "minitri":
        r = A.minitri(g)
    elif algo == "dfs":
        r = A.dfs(g, 0)
    else:
        raise ValueError(algo)
    wall = time.time() - t0
    return r, wall


def platform_reports(g, algo: str, b: int = 16, num_clusters: int = 64):
    """(nale, cpu, gpu) PlatformReports for one (graph, algorithm)."""
    ra, wall_a = run_algo(g, algo, "async", b, num_clusters)
    if algo in ("minitri", "dfs"):
        rs, wall_s = ra, wall_a  # one-shot / sequential: same schedule
    else:
        rs, wall_s = run_algo(g, algo, "sync", b, num_clusters)
    prep = ra.prepared
    if prep is None:  # minitri / dfs have no BSR image; synthesize one
        from repro.core import engine as eng
        prep = eng.prepare(g, "min_plus", b=b, num_clusters=num_clusters)
    k_pad = max(float(np.diff(g.indptr).max()), 1.0)
    nale = PW.model_nale(prep, ra.stats)
    cpu = PW.model_cpu(prep, ra.stats)
    gpu = PW.model_gpu(prep, rs.stats, k_max_pad=k_pad,
                       avg_degree=g.avg_degree)
    return dict(nale=nale, cpu=cpu, gpu=gpu, async_stats=ra.stats,
                sync_stats=rs.stats, wall_async=wall_a, wall_sync=wall_s)


def csv_line(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.2f},{derived}")
