"""Shared benchmark plumbing: paper workloads at configurable scale,
platform models, CSV emission.

Benchmarks go through the session API (``repro.api.GraphProcessor``):
one processor per graph, so every algorithm × mode combination reuses
the cached compile-time pipeline (clustering, BSR build, upload) —
the serving shape the repo is growing toward.
"""

from __future__ import annotations

import os
import time

import numpy as np

from repro import api
from repro.core import graph as G
from repro.core import power as PW

SCALE = float(os.environ.get("REPRO_BENCH_SCALE", 1.0 / 256))

ALGOS = ["sssp", "bfs", "pagerank", "cc", "minitri", "dfs"]
GRAPH_NAMES = ["ca", "fb", "lj"]


def load_graphs(scale: float = SCALE):
    return {name: G.make_paper_graph(name, scale=scale, seed=7)
            for name in GRAPH_NAMES}


def processor(g, b: int = 16,
              num_clusters: int = 64) -> api.GraphProcessor:
    """One session per (graph, tiling) — plans are cached across calls."""
    sessions = g.__dict__.setdefault("_bench_sessions", {})
    key = (b, num_clusters)
    if key not in sessions:
        sessions[key] = api.GraphProcessor(g, b=b,
                                           num_clusters=num_clusters)
    return sessions[key]


def run_algo(g, algo: str, mode: str, b: int = 16, num_clusters: int = 64):
    proc = processor(g, b, num_clusters)
    pol = api.ExecutionPolicy(mode=mode, max_sweeps=100_000)
    t0 = time.time()
    if algo == "sssp":
        r = proc.sssp(0, policy=pol)
    elif algo == "bfs":
        r = proc.bfs(0, policy=pol)
    elif algo == "pagerank":
        r = proc.pagerank(policy=pol.but(tol=1e-7, max_sweeps=500))
    elif algo == "cc":
        r = proc.connected_components(policy=pol)
    elif algo == "minitri":
        r = proc.minitri()
    elif algo == "dfs":
        r = proc.dfs(0)
    else:
        raise ValueError(algo)
    wall = time.time() - t0
    return r, wall


def platform_reports(g, algo: str, b: int = 16, num_clusters: int = 64):
    """(nale, cpu, gpu) PlatformReports for one (graph, algorithm)."""
    ra, wall_a = run_algo(g, algo, "async", b, num_clusters)
    if algo in ("minitri", "dfs"):
        rs, wall_s = ra, wall_a  # one-shot / sequential: same schedule
    else:
        rs, wall_s = run_algo(g, algo, "sync", b, num_clusters)
    prep = ra.prepared
    if prep is None:  # minitri / dfs have no BSR image; borrow a plan
        prep = processor(g, b, num_clusters).prepare("min_plus")
    k_pad = max(float(np.diff(g.indptr).max()), 1.0)
    nale = PW.model_nale(prep, ra.stats)
    cpu = PW.model_cpu(prep, ra.stats)
    gpu = PW.model_gpu(prep, rs.stats, k_max_pad=k_pad,
                       avg_degree=g.avg_degree)
    return dict(nale=nale, cpu=cpu, gpu=gpu, async_stats=ra.stats,
                sync_stats=rs.stats, wall_async=wall_a, wall_sync=wall_s)


def csv_line(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.2f},{derived}")
