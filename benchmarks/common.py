"""Shared benchmark plumbing: paper workloads at configurable scale,
platform models, CSV emission.

Benchmarks go through the serving layer (``repro.api.GraphService``):
one service for the whole run, so every graph × algorithm × mode
combination borrows plans from the shared LRU store (clustering, BSR
build, upload each happen once) and the run can report the store's hit
rate.  Set ``REPRO_PLAN_CACHE=<dir>`` to persist plans across benchmark
invocations (a warm re-run then skips the compile pipeline entirely).
"""

from __future__ import annotations

import os
import time
from typing import Optional

import numpy as np

from repro import api
from repro.core import graph as G
from repro.core import power as PW

SCALE = float(os.environ.get("REPRO_BENCH_SCALE", 1.0 / 256))

ALGOS = ["sssp", "bfs", "pagerank", "cc", "minitri", "dfs"]
GRAPH_NAMES = ["ca", "fb", "lj"]

_SERVICE: Optional[api.GraphService] = None


def service() -> api.GraphService:
    """The run-wide GraphService (plan store shared by all benchmarks).

    The byte budget defaults high (8 GB): benchmark plans for the
    power-law graphs run hundreds of MB each, and an evicting store
    would silently re-run the compile pipeline mid-benchmark.
    """
    global _SERVICE
    if _SERVICE is None:
        _SERVICE = api.GraphService(
            max_plan_bytes=int(os.environ.get("REPRO_PLAN_BYTES",
                                              8 << 30)),
            cache_dir=os.environ.get("REPRO_PLAN_CACHE") or None)
    return _SERVICE


def load_graphs(scale: float = SCALE):
    return {name: G.make_paper_graph(name, scale=scale, seed=7)
            for name in GRAPH_NAMES}


def register_name(g, b: int = 16, num_clusters: int = 64) -> str:
    """Canonical service-registry name for a (graph, tiling) session —
    shared so the serving benchmarks can submit against the same
    registration ``processor()`` created."""
    return f"{g.fingerprint()[:12]}/b{b}c{num_clusters}"


def processor(g, b: int = 16,
              num_clusters: int = 64) -> api.GraphProcessor:
    """One registered session per (graph, tiling); registration is
    idempotent, so repeat calls return the same processor."""
    return service().register(register_name(g, b, num_clusters), g, b=b,
                              num_clusters=num_clusters)


# per-algorithm policy overrides on top of the benchmark baseline
# (mode=<caller>, max_sweeps=100_000) — the historical fig5/fig6 knobs
_BENCH_POLICY = {
    "pagerank": dict(tol=1e-7, max_sweeps=500),
    "pagerank_delta": dict(tol=1e-7, max_sweeps=500),
}


def run_algo(g, algo: str, mode: str, b: int = 16, num_clusters: int = 64,
             **params):
    """Registry-generic single-query run: any registered algorithm
    dispatches through one QuerySpec (``params`` ride along, e.g.
    ``k=2.0`` for kcore) — no per-name branches."""
    proc = processor(g, b, num_clusters)
    a = api.get_algorithm(algo)
    pol = api.ExecutionPolicy(mode=mode, max_sweeps=100_000).but(
        **_BENCH_POLICY.get(algo, {}))
    spec = api.QuerySpec(algo=algo,
                         sources=(0,) if a.source_required else (),
                         policy=pol, params=params)
    t0 = time.time()
    r = proc.run(spec)
    wall = time.time() - t0
    return r, wall


def run_batched(g, algo: str, sources, mode: str = "distributed",
                query_axis=None, b: int = 16, num_clusters: int = 64,
                dist_flavor: str = "sync", local_sweeps: int = 1):
    """Multi-source batched run (the ``distributed_batched`` and
    ``dist_async`` sweep families' entry point).  ``query_axis=None``
    auto-factors the device count over the 2-D ("graph", "query") mesh;
    ``query_axis=0`` is the per-source sequential escape hatch used as a
    comparison baseline; ``dist_flavor="async"`` + ``local_sweeps=k``
    selects the self-timed engine (k local sweeps per halo exchange)."""
    proc = processor(g, b, num_clusters)
    pol = api.ExecutionPolicy(mode=mode, max_sweeps=100_000,
                              query_axis=query_axis,
                              dist_flavor=dist_flavor,
                              local_sweeps=local_sweeps)
    t0 = time.time()
    if algo == "sssp":
        r = proc.sssp(sources=list(sources), policy=pol)
    elif algo == "bfs":
        r = proc.bfs(sources=list(sources), policy=pol)
    else:
        raise ValueError(f"batched family supports sssp|bfs, not {algo}")
    return r, time.time() - t0


def platform_reports(g, algo: str, b: int = 16, num_clusters: int = 64,
                     **params):
    """(nale, cpu, gpu) PlatformReports for one (graph, algorithm)."""
    ra, wall_a = run_algo(g, algo, "async", b, num_clusters, **params)
    if api.get_algorithm(algo).runner is not None:
        rs, wall_s = ra, wall_a  # one-shot / sequential: same schedule
    else:
        rs, wall_s = run_algo(g, algo, "sync", b, num_clusters, **params)
    prep = ra.prepared
    if prep is None:  # minitri / dfs have no BSR image; borrow a plan
        prep = processor(g, b, num_clusters).prepare("min_plus")
    k_pad = max(float(np.diff(g.indptr).max()), 1.0)
    nale = PW.model_nale(prep, ra.stats)
    cpu = PW.model_cpu(prep, ra.stats)
    gpu = PW.model_gpu(prep, rs.stats, k_max_pad=k_pad,
                       avg_degree=g.avg_degree)
    return dict(nale=nale, cpu=cpu, gpu=gpu, async_stats=ra.stats,
                sync_stats=rs.stats, wall_async=wall_a, wall_sync=wall_s)


def csv_line(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.2f},{derived}")
