"""Kernel microbenchmarks: bsr_spmv (ref XLA path wall-clock on CPU —
the Pallas path is TPU-target, validated in interpret mode by tests) and
flash-attention reference, plus modeled TPU roofline per kernel call."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine as eng
from repro.core import graph as G

from . import common


def _time(fn, *args, iters=20):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) \
        else jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters


def run(graphs=None, emit=common.csv_line):
    from repro.kernels import ops
    rows = []
    g = G.rmat(4096, 32768, seed=3)
    p = eng.prepare(g, "plus_times", b=32, num_clusters=64,
                    normalize="out_stochastic")
    x = jnp.asarray(np.random.default_rng(0)
                    .random((p.r_pad, p.b)).astype(np.float32))

    def spmv(xv):
        return ops.bsr_spmv(p.vals, p.cols, p.nnz, xv,
                            semiring="plus_times", impl="ref")

    jspmv = jax.jit(spmv)
    dt = _time(lambda xv: jspmv(xv), x)
    flops = 2.0 * p.tiles_total * p.b * p.b
    emit("kernel/bsr_spmv_ref_cpu", dt * 1e6,
         f"gflops={flops/dt/1e9:.2f} tiles={int(p.tiles_total)}")
    # modeled TPU: tiles stream HBM→VMEM at 819 GB/s; MXU does the MACs
    tile_bytes = p.tiles_total * p.b * p.b * 4
    t_mem = tile_bytes / 819e9
    t_mxu = flops / 197e12
    emit("kernel/bsr_spmv_tpu_model", 0.0,
         f"t_mem_us={t_mem*1e6:.1f} t_mxu_us={t_mxu*1e6:.2f} "
         f"bound={'memory' if t_mem > t_mxu else 'compute'}")
    rows.append(dict(kernel="bsr_spmv", cpu_us=dt * 1e6,
                     gflops=flops / dt / 1e9,
                     tpu_t_mem_us=t_mem * 1e6, tpu_t_mxu_us=t_mxu * 1e6))

    b, h, s, d = 1, 8, 2048, 64
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.bfloat16)
    att = jax.jit(lambda q, k, v: ops.attention(q, k, v, causal=True))
    dt = _time(lambda a, b_, c: att(a, b_, c), q, k, v)
    aflops = 4.0 * b * h * s * s / 2 * d
    emit("kernel/attention_ref_cpu", dt * 1e6,
         f"gflops={aflops/dt/1e9:.2f}")
    rows.append(dict(kernel="attention", cpu_us=dt * 1e6,
                     gflops=aflops / dt / 1e9))
    return rows


# --------------------------------------------------------------------------
# kernel_fused — active-tile skipping of the fused frontier-masked kernel
# --------------------------------------------------------------------------
#
# The gated number is the MODELED speedup of the fused sweep loop over the
# unfused sync loop on a point-source (sparse-frontier) workload:
#
#   t_mode = tile_work · (B²·4 bytes) / HBM_BW + sweeps · launches · 1 µs
#
# tile_work comes from the engines' measured per-sweep counters (the fused
# loop charges only the rows its active list walked), so tiles_skipped is
# a measured property of the frontier trajectory, deterministic for a
# given scale/seed.  The launch term models the fusion itself: the unfused
# sweep is three dispatches (SpMV, apply/select, convergence reduce); the
# fused kernel is one.  The road-network entry is the canonical
# sparse-frontier case (long diameter, narrow wavefront) the >1.5×/≥50%
# acceptance bar refers to; a small fixed-size power-law RMAT rides along
# to show the dense-frontier end of the range.  (The family runs its own
# graphs rather than the paper trio: the fused path executes in Pallas
# interpret mode on CPU, whose per-sweep cost grows with grid × plan
# bytes — the paper graphs belong to the compiled-TPU path, not a CPU
# correctness sweep.)

HBM_BW = 819e9
LAUNCH_S = 1e-6
SWEEP_LAUNCHES_SYNC = 3    # spmv + apply/select + reduce
SWEEP_LAUNCHES_FUSED = 1


def _modeled_s(tile_work: float, b: int, sweeps: int,
               launches: int) -> float:
    return (tile_work * (b * b * 4) / HBM_BW
            + sweeps * launches * LAUNCH_S)


def run_fused(scale: float = None, emit=common.csv_line):
    import time as _t

    from repro import api

    scale = common.SCALE if scale is None else scale
    side = max(8, int(round(40 * (scale * 256) ** 0.5)))
    cases = {"road": G.road_network(side, seed=5),
             "rmat": G.rmat(512, 2048, seed=3)}

    pol_sync = api.ExecutionPolicy(mode="sync", max_sweeps=100_000)
    pol_fused = pol_sync.but(kernel=api.KernelSpec(
        impl="pallas", fuse_frontier=True, block_size=8))
    rows = []
    for gname, g in cases.items():
        proc = common.processor(g)
        for algo in ("bfs", "sssp"):
            res = {}
            wall = {}
            for label, pol in (("sync", pol_sync), ("fused", pol_fused)):
                t0 = _t.time()
                res[label] = (proc.bfs(0, policy=pol) if algo == "bfs"
                              else proc.sssp(0, policy=pol))
                wall[label] = _t.time() - t0
            st_s, st_f = res["sync"].stats, res["fused"].stats
            if not np.allclose(res["sync"].values, res["fused"].values,
                               equal_nan=True):
                raise AssertionError(
                    f"fused != sync values on {gname}/{algo}")
            skipped = 1.0 - st_f.tile_work / max(st_s.tile_work, 1.0)
            b = res["sync"].prepared.b
            t_s = _modeled_s(st_s.tile_work, b, st_s.sweeps,
                             SWEEP_LAUNCHES_SYNC)
            t_f = _modeled_s(st_f.tile_work, b, st_f.sweeps,
                             SWEEP_LAUNCHES_FUSED)
            speedup = t_s / t_f
            emit(f"kernel_fused/{gname}/{algo}", wall["fused"] * 1e6,
                 f"tiles_skipped={skipped:.2f} "
                 f"speedup_modeled={speedup:.2f} sweeps={st_f.sweeps}")
            rows.append(dict(
                graph=gname, algo=algo, sweeps=st_f.sweeps,
                tile_work_sync=st_s.tile_work,
                tile_work_fused=st_f.tile_work,
                tiles_skipped=skipped, speedup_modeled=speedup,
                wall_sync_ms=wall["sync"] * 1e3,
                wall_fused_ms=wall["fused"] * 1e3))
    return rows
