"""Kernel microbenchmarks: bsr_spmv (ref XLA path wall-clock on CPU —
the Pallas path is TPU-target, validated in interpret mode by tests) and
flash-attention reference, plus modeled TPU roofline per kernel call."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine as eng
from repro.core import graph as G

from . import common


def _time(fn, *args, iters=20):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) \
        else jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters


def run(graphs=None, emit=common.csv_line):
    from repro.kernels import ops
    rows = []
    g = G.rmat(4096, 32768, seed=3)
    p = eng.prepare(g, "plus_times", b=32, num_clusters=64,
                    normalize="out_stochastic")
    x = jnp.asarray(np.random.default_rng(0)
                    .random((p.r_pad, p.b)).astype(np.float32))

    def spmv(xv):
        return ops.bsr_spmv(p.vals, p.cols, p.nnz, xv,
                            semiring="plus_times", impl="ref")

    jspmv = jax.jit(spmv)
    dt = _time(lambda xv: jspmv(xv), x)
    flops = 2.0 * p.tiles_total * p.b * p.b
    emit("kernel/bsr_spmv_ref_cpu", dt * 1e6,
         f"gflops={flops/dt/1e9:.2f} tiles={int(p.tiles_total)}")
    # modeled TPU: tiles stream HBM→VMEM at 819 GB/s; MXU does the MACs
    tile_bytes = p.tiles_total * p.b * p.b * 4
    t_mem = tile_bytes / 819e9
    t_mxu = flops / 197e12
    emit("kernel/bsr_spmv_tpu_model", 0.0,
         f"t_mem_us={t_mem*1e6:.1f} t_mxu_us={t_mxu*1e6:.2f} "
         f"bound={'memory' if t_mem > t_mxu else 'compute'}")
    rows.append(dict(kernel="bsr_spmv", cpu_us=dt * 1e6,
                     gflops=flops / dt / 1e9,
                     tpu_t_mem_us=t_mem * 1e6, tpu_t_mxu_us=t_mxu * 1e6))

    b, h, s, d = 1, 8, 2048, 64
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.bfloat16)
    att = jax.jit(lambda q, k, v: ops.attention(q, k, v, causal=True))
    dt = _time(lambda a, b_, c: att(a, b_, c), q, k, v)
    aflops = 4.0 * b * h * s * s / 2 * d
    emit("kernel/attention_ref_cpu", dt * 1e6,
         f"gflops={aflops/dt/1e9:.2f}")
    rows.append(dict(kernel="attention", cpu_us=dt * 1e6,
                     gflops=aflops / dt / 1e9))
    return rows
