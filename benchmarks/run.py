"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines, then a summary that checks
the paper's headline claims:
  * 10–20× speedup vs a comparable CPU (modeled cycles, Fig. 5)
  * 2–5× better power efficiency vs a GPU (modeled, Fig. 6)
and the directly MEASURED async-vs-sync work reduction the claims rest on.

A machine-readable snapshot (per-algorithm sweeps, edge_work, crit_tiles,
modeled speedups) is written to ``BENCH_graph.json`` by default so later
PRs have a perf trajectory to diff against; ``--json ''`` disables it.

  PYTHONPATH=src python -m benchmarks.run [--scale 1/256] [--json out]
"""

from __future__ import annotations

import argparse
import json

import numpy as np

from . import algo_suite, async_vs_sync, common, dist_async, \
    dist_batched, fig5_cycles, fig6_power, kernel_bench, lm_bench, \
    serve_latency


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=common.SCALE,
                    help="fraction of full paper graph size (default "
                         "1/256; 1.0 = paper scale)")
    ap.add_argument("--json", default="BENCH_graph.json",
                    help="output path for the machine-readable snapshot "
                         "('' disables)")
    ap.add_argument("--skip", nargs="*", default=[],
                    choices=["fig5", "fig6", "avs", "dist", "dist_async",
                             "kernel", "kernel_fused", "lm", "serve",
                             "algo_suite"])
    args = ap.parse_args()

    graphs = common.load_graphs(args.scale)
    out = {"meta": {"scale": args.scale,
                    "graphs": {name: dict(n=g.n, nnz=g.nnz,
                                          avg_degree=g.avg_degree)
                               for name, g in graphs.items()}}}
    for name, g in graphs.items():
        common.csv_line(f"graph/{name}", 0.0,
                        f"n={g.n} nnz={g.nnz} avg_deg={g.avg_degree:.2f}")
    if "fig5" not in args.skip:
        out["fig5"] = fig5_cycles.run(graphs)
    if "fig6" not in args.skip:
        out["fig6"] = fig6_power.run(graphs)
    if "algo_suite" not in args.skip:
        out["algo_suite"] = algo_suite.run(graphs)
    if "avs" not in args.skip:
        out["async_vs_sync"] = async_vs_sync.run(graphs)
    if "dist" not in args.skip:
        out["distributed_batched"] = dist_batched.run(graphs)
    if "dist_async" not in args.skip:
        out["dist_async"] = dist_async.run(graphs)
    if "serve" not in args.skip:
        out["serve_latency"] = serve_latency.run(graphs)
    if "kernel" not in args.skip:
        out["kernel"] = kernel_bench.run(graphs)
    if "kernel_fused" not in args.skip:
        out["kernel_fused"] = kernel_bench.run_fused(args.scale)
    if "lm" not in args.skip:
        out["lm"] = lm_bench.run(graphs)

    # --- paper-claim summary -------------------------------------------
    if "fig5" in out:
        par = [r for r in out["fig5"] if r["algo"] not in ("dfs",)]
        sp = np.array([r["speedup_cpu"] for r in par])
        gp = [r["perf_per_watt_vs_gpu"] for r in out.get("fig6", [])
              if r["algo"] not in ("dfs",)]
        print("\n== paper-claim check (modeled; constants in "
              "core/power.py) ==")
        print(f"speedup vs CPU  : geomean {np.exp(np.log(sp).mean()):.1f}x"
              f"  range [{sp.min():.1f}, {sp.max():.1f}]  "
              f"(paper: 10-20x)")
        if gp:
            gp = np.array(gp)
            print(f"perf/W vs GPU   : geomean "
                  f"{np.exp(np.log(gp).mean()):.1f}x  "
                  f"range [{gp.min():.1f}, {gp.max():.1f}]  (paper: 2-5x)")
    if "algo_suite" in out:
        asp = np.array([r["speedup_cpu"] for r in out["algo_suite"]])
        print(f"algorithm catalog (pagerank_delta/cc/kcore/tricount, "
              f"modeled): geomean {np.exp(np.log(asp).mean()):.1f}x vs "
              f"CPU  range [{asp.min():.1f}, {asp.max():.1f}]")
    if "async_vs_sync" in out:
        wr = [r["work_reduction"] for r in out["async_vs_sync"]
              if "work_reduction" in r]
        print(f"async work reduction (measured): geomean "
              f"{np.exp(np.log(wr).mean()):.2f}x over bulk-synchronous")
    if "distributed_batched" in out:
        ds = np.array([r["speedup_vs_sequential"]
                       for r in out["distributed_batched"]])
        print(f"batched distributed dispatch (modeled, "
              f"{dist_batched.REF_DEVICES}-device node): geomean "
              f"{np.exp(np.log(ds).mean()):.2f}x vs per-source loop")
    if "dist_async" in out:
        da = out["dist_async"]
        sp = np.array([r["speedup_vs_sync"] for r in da])
        hr = np.array([r["halo_exchange_reduction"] for r in da])
        print(f"self-timed distributed engine (modeled): geomean "
              f"{np.exp(np.log(sp).mean()):.2f}x vs bulk-synchronous, "
              f"halo exchanges cut {np.exp(np.log(hr).mean()):.2f}x")
    if "kernel_fused" in out:
        kf = out["kernel_fused"]
        sp = np.array([r["speedup_modeled"] for r in kf])
        sk = np.array([r["tiles_skipped"] for r in kf])
        road = [r for r in kf if r["graph"] == "road" and r["algo"] == "bfs"]
        print(f"fused frontier-masked kernel (modeled): geomean "
              f"{np.exp(np.log(sp).mean()):.2f}x vs unfused sync loop, "
              f"tiles skipped {sk.min():.0%}..{sk.max():.0%}"
              + (f" (sparse-frontier BFS: {road[0]['speedup_modeled']:.2f}x,"
                 f" {road[0]['tiles_skipped']:.0%} skipped)" if road else ""))
    if "serve_latency" in out:
        sl = out["serve_latency"]
        sp = np.array([r["speedup_vs_unbatched"] for r in sl])
        aw = np.mean([r["achieved_wave"] for r in sl])
        p99 = max(r["p99_ms"] for r in sl)
        print(f"continuous-batching front door: geomean modeled "
              f"{np.exp(np.log(sp).mean()):.2f}x vs unbatched dispatch "
              f"(achieved wave {aw:.1f}, worst p99 {p99:.1f} ms)")

    # --- serving-layer accounting --------------------------------------
    store = common.service().store.stats()
    out["plan_store"] = store
    print(f"plan store: {store['plans']} plans "
          f"({store['bytes'] / 1e6:.2f} MB), hit rate "
          f"{store['hit_rate']:.1%} = {store['mem_hit_rate']:.1%} mem "
          f"+ {store['disk_hit_rate']:.1%} disk "
          f"({store['mem_hits']} mem hits, {store['disk_hits']} disk "
          f"hits, {store['misses']} builds)")

    if args.json:
        with open(args.json, "w") as f:
            json.dump(out, f, indent=1, default=float)
        print(f"\nwrote {args.json}")


if __name__ == '__main__':
    main()
