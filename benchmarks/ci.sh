#!/usr/bin/env bash
# CI entry point: tier-1 test suite + CPU smoke of the session-API
# quickstart.  Mirrors .github/workflows/ci.yml for local use.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

echo "== tier-1 pytest =="
python -m pytest -x -q

echo "== quickstart smoke (CPU) =="
python examples/quickstart.py

echo "CI OK"
