#!/usr/bin/env bash
# CI entry point: tier-1 test suite + CPU smoke of the session-API
# quickstart.  Mirrors .github/workflows/ci.yml for local use.
#
# DEVICES=N (default 1) switches to the multi-device lane: the process
# gets N fake host devices (XLA_FLAGS=--xla_force_host_platform_device_
# count=N) so the distributed engines — including the 2-D
# ("graph", "query") batched mesh — run in-process against a real
# device grid instead of only via subprocess tests.
#
# FAULTS=1 switches to the fault-injection smoke lane: the resilience
# suite (deterministic FaultPlan seed, REPRO_FAULT_SEED, default 1234)
# replays injected failures at every registered site and asserts the
# recovery machinery — retries, the degradation ladder, PlanStore
# quarantine, the wave watchdog — absorbs them.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"
DEVICES="${DEVICES:-1}"
FAULTS="${FAULTS:-0}"

# pytest-timeout turns a hung wave/retry test into a loud failure
# instead of a 45-minute lane timeout; the flag is gated so local runs
# without the plugin still work.
TIMEOUT_FLAGS=""
if python -c "import pytest_timeout" >/dev/null 2>&1; then
    TIMEOUT_FLAGS="--timeout=600 --timeout-method=thread"
fi

if [ "$FAULTS" = "1" ]; then
    export REPRO_FAULT_SEED="${REPRO_FAULT_SEED:-1234}"
    echo "== fault-injection smoke lane (seed ${REPRO_FAULT_SEED}) =="
    python -m pytest -x -q ${TIMEOUT_FLAGS} tests/test_resilience.py
    echo "CI OK (fault injection, seed ${REPRO_FAULT_SEED})"
    exit 0
fi

if [ "$DEVICES" -gt 1 ]; then
    export XLA_FLAGS="--xla_force_host_platform_device_count=${DEVICES}${XLA_FLAGS:+ ${XLA_FLAGS}}"
    echo "== multi-device lane: distributed engines on ${DEVICES} fake host devices =="
    # distribution suite (2-D mesh parity across factorizations runs
    # in-process here) + the self-timed async engine (async-vs-sync
    # bit-identity across factorizations × k) + the session-API suite
    # (batched distributed dispatch through GraphProcessor/
    # ExecutionPolicy) + the continuous-batching server (wave scheduler
    # over a real device grid)
    # ... + the algorithm-catalog parity grid (pagerank_delta / cc /
    # kcore / tricount through every engine flavor on the device grid)
    # ... + the resilience suite (fault sites in the distributed
    # engines exercise a real device grid here)
    python -m pytest -x -q ${TIMEOUT_FLAGS} tests/test_distribution.py \
        tests/test_async_dist.py tests/test_api.py \
        tests/test_graph_server.py tests/test_algorithms.py \
        tests/test_resilience.py
    echo "== batched distributed + serve sweep families (${DEVICES} devices) =="
    python -m benchmarks.run --scale 0.002 --json BENCH_multidev.json \
        --skip fig5 fig6 avs kernel lm
    echo "CI OK (multi-device, DEVICES=${DEVICES})"
    exit 0
fi

echo "== tier-1 pytest =="
python -m pytest -x -q ${TIMEOUT_FLAGS}

echo "== quickstart smoke (CPU) =="
python examples/quickstart.py

echo "== bench trend vs committed BENCH_graph.json (incl. serve load-test smoke) =="
# re-run the modeled benchmarks at the committed snapshot's scale and
# gate on >25% modeled-speedup regression (also reports the plan-store
# per-tier hit rates for the run).  The run includes the serve_latency
# load-test smoke: concurrent clients against a GraphServer, emitting
# p50/p99 + achieved wave size, with the modeled batching speedup
# protected by the trend gate below.
SCALE=$(python -c "import json; \
    print(json.load(open('BENCH_graph.json'))['meta']['scale'])")
python -m benchmarks.run --scale "$SCALE" --json BENCH_ci.json \
    --skip kernel lm
python -m benchmarks.trend_check BENCH_graph.json BENCH_ci.json \
    --threshold 0.25

echo "CI OK"
