#!/usr/bin/env bash
# CI entry point: tier-1 test suite + CPU smoke of the session-API
# quickstart.  Mirrors .github/workflows/ci.yml for local use.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

echo "== tier-1 pytest =="
python -m pytest -x -q

echo "== quickstart smoke (CPU) =="
python examples/quickstart.py

echo "== bench trend vs committed BENCH_graph.json =="
# re-run the modeled benchmarks at the committed snapshot's scale and
# gate on >25% modeled-speedup regression (also reports the plan-store
# hit rate for the run)
SCALE=$(python -c "import json; \
    print(json.load(open('BENCH_graph.json'))['meta']['scale'])")
python -m benchmarks.run --scale "$SCALE" --json BENCH_ci.json \
    --skip kernel lm
python -m benchmarks.trend_check BENCH_graph.json BENCH_ci.json \
    --threshold 0.25

echo "CI OK"
