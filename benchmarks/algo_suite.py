"""Algorithm-catalog suite — the PR-9 families (pagerank_delta / cc /
kcore / tricount) on the paper's stand-in graphs, through the same
measured-counters → modeled-cycles pipeline as Fig. 5.  Everything
dispatches registry-generically (``common.run_algo`` builds one
QuerySpec per row); ``kcore`` shows the params-passthrough path.

Gated by ``trend_check.py`` on the modeled CPU speedup per
(graph, algorithm) row, alongside the fig5 family.
"""

from __future__ import annotations

from . import common

# (algorithm, params) rows; params ride the QuerySpec (kcore's k lands
# in the policy's scalar slot via the registry's param_map)
ALGOS = [
    ("pagerank_delta", {}),
    ("cc", {}),
    ("kcore", {"k": 2.0}),
    ("tricount", {}),
]


def run(graphs=None, emit=common.csv_line):
    graphs = graphs or common.load_graphs()
    rows = []
    for gname, g in graphs.items():
        for algo, params in ALGOS:
            rep = common.platform_reports(g, algo, **params)
            nale, cpu, gpu = rep["nale"], rep["cpu"], rep["gpu"]
            speedup_cpu = cpu.time_s / max(nale.time_s, 1e-12)
            speedup_gpu = gpu.time_s / max(nale.time_s, 1e-12)
            emit(f"algo_suite/{gname}/{algo}/nale_cycles",
                 rep["wall_async"] * 1e6,
                 f"cycles={nale.cycles:.3g}")
            emit(f"algo_suite/{gname}/{algo}/speedup", 0.0,
                 f"vs_cpu={speedup_cpu:.1f}x vs_gpu={speedup_gpu:.1f}x")
            rows.append(dict(graph=gname, algo=algo, params=params,
                             nale_cycles=nale.cycles,
                             cpu_cycles=cpu.cycles,
                             gpu_cycles=gpu.cycles,
                             speedup_cpu=speedup_cpu,
                             speedup_gpu=speedup_gpu,
                             sweeps_async=rep["async_stats"].sweeps,
                             sweeps_sync=rep["sync_stats"].sweeps,
                             edge_work_async=rep["async_stats"].edge_work,
                             edge_work_sync=rep["sync_stats"].edge_work))
    return rows
