"""``distributed_batched`` sweep family: Q-source SSSP/BFS through the
2-D ("graph" × "query") mesh engine vs the retired per-source sequential
loop (the ``query_axis=0`` escape hatch).

Both paths are bit-identical in VALUES; what the batch buys is dispatch
parallelism, so the speedup is MODELED the same way fig5 models
platforms: per-query NALE critical paths from the measured sweep counts,
executed back-to-back (sequential) vs in straggler-bound query-waves on
a reference 8-device node (the CI multi-device lane's shape).  Modeled
numbers are deterministic for a given scale/seed regardless of how many
real devices this process has — the trend gate depends on engine work
counters, not the host.
"""

from __future__ import annotations

import numpy as np

from repro.core import engine as eng
from repro.core import placement as PL
from repro.core import power as PW

from . import common

QUERIES = 4        # sources per batch
REF_DEVICES = 8    # modeled node size (matches the CI multi-device lane)


def run(graphs=None, emit=common.csv_line):
    graphs = graphs or common.load_graphs()
    rows = []
    for gname, g in graphs.items():
        sources = [int(s) for s in
                   np.linspace(0, g.n - 1, QUERIES, dtype=np.int64)]
        for algo in ("sssp", "bfs"):
            rb, wall_b = common.run_batched(g, algo, sources)
            rs, wall_s = common.run_batched(g, algo, sources,
                                            query_axis=0)
            dist = rb.extra["dist"]
            p = rb.prepared
            qs = dist.query_sweeps
            # sequential: Q dispatches back to back — cycles add up
            seq_s = sum(
                PW.model_nale(p, eng.bsp_stats(p, int(sq), True,
                                               "distributed")).time_s
                for sq in qs)
            # batched: queries ride concurrently over the "query" axis;
            # each wave of q_ref is bound by its straggler
            q_ref = PL.factor_query_axis(REF_DEVICES, len(sources))
            waves = -(-len(sources) // q_ref)
            bat_s = waves * PW.model_nale(
                p, eng.bsp_stats(p, int(qs.max(initial=0)), True,
                                 "distributed")).time_s
            speedup = seq_s / max(bat_s, 1e-12)
            emit(f"dist_batched/{gname}/{algo}", wall_b * 1e6,
                 f"Q={len(sources)} mesh={dist.mesh_shape} "
                 f"straggler={dist.sweeps} "
                 f"work_sweeps={int(qs.sum())} "
                 f"modeled_speedup={speedup:.2f}x")
            rows.append(dict(
                graph=gname, algo=algo, queries=len(sources),
                mesh_graph=dist.mesh_shape[0],
                mesh_query=dist.mesh_shape[1],
                sweeps=dist.sweeps,
                query_sweeps=[int(sq) for sq in qs],
                work_sweeps=int(qs.sum()),
                ref_devices=REF_DEVICES, ref_query_axis=q_ref,
                speedup_vs_sequential=speedup,
                wall_batched_s=wall_b, wall_sequential_s=wall_s))
    return rows
