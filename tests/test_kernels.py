"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py
oracles vs dense numpy ground truth."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import graph as G
from repro.core import semiring as sr
from repro.kernels import ops, ref

SEMIRINGS = ["plus_times", "min_plus", "max_min", "min_select"]


def _dense_spmv(a, x, name):
    if name == "plus_times":
        return a @ x
    if name == "min_plus":
        return np.min(a + x[None, :], axis=1)
    if name == "max_min":
        return np.max(np.minimum(a, x[None, :]), axis=1)
    return np.min(np.where(np.isfinite(a), x[None, :], np.inf), axis=1)


@pytest.mark.parametrize("semiring", SEMIRINGS)
@pytest.mark.parametrize("n,e,b,bk", [(64, 256, 8, 2), (200, 800, 16, 4),
                                      (120, 900, 32, 8)])
def test_bsr_spmv_sweep(semiring, n, e, b, bk, rng):
    g = G.rmat(n, e, seed=n + e)
    bsr = G.to_bsr(g, b=b, pad_value=float(sr.get(semiring).zero))
    x = rng.random((bsr.r, bsr.b)).astype(np.float32)
    if semiring == "max_min":
        x = (x > 0.5).astype(np.float32)
    args = (jnp.asarray(bsr.block_vals), jnp.asarray(bsr.block_cols),
            jnp.asarray(bsr.block_nnz), jnp.asarray(x))
    y_ref = ops.bsr_spmv(*args, semiring=semiring, impl="ref")
    y_pal = ops.bsr_spmv(*args, semiring=semiring, impl="pallas", bk=bk)
    dense = _dense_spmv(G.bsr_to_dense(bsr), x.reshape(-1), semiring)
    np.testing.assert_allclose(np.asarray(y_ref).reshape(-1), dense,
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(y_pal).reshape(-1), dense,
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, None), (False, None),
                                           (True, 64)])
@pytest.mark.parametrize("b,h,kv,s,d", [(2, 4, 2, 256, 64),
                                        (1, 2, 1, 128, 32)])
def test_flash_attention_sweep(dtype, causal, window, b, h, kv, s, d, rng):
    q = jnp.asarray(rng.standard_normal((b, h, s, d)), dtype)
    k = jnp.asarray(rng.standard_normal((b, kv, s, d)), dtype)
    v = jnp.asarray(rng.standard_normal((b, kv, s, d)), dtype)
    o_ref = ops.attention(q, k, v, causal=causal, window=window,
                          impl="ref")
    o_pal = ops.attention(q, k, v, causal=causal, window=window,
                          impl="pallas", bq=64, bk=64)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(o_ref, np.float32),
                               np.asarray(o_pal, np.float32),
                               rtol=tol, atol=tol)


def test_chunked_attention_matches_exact(rng):
    b, h, s, d = 1, 2, 2048, 32
    q = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
    exact = ref.mha_ref(q, k, v, causal=True)
    chunk = ref.mha_chunked(q, k, v, causal=True, q_chunk=256)
    np.testing.assert_allclose(np.asarray(exact), np.asarray(chunk),
                               rtol=2e-5, atol=2e-5)


def test_bsr_padding_is_noop(rng):
    """Padding tiles hold ⊕-identities: adding empty tiles never changes
    the result (the kernel's 'empty FIFO slot' invariant)."""
    g = G.rmat(50, 200, seed=3)
    for name in SEMIRINGS:
        z = float(sr.get(name).zero)
        bsr = G.to_bsr(g, b=8, pad_value=z)
        x = rng.random((bsr.r, bsr.b)).astype(np.float32)
        y0 = ops.bsr_spmv(jnp.asarray(bsr.block_vals),
                          jnp.asarray(bsr.block_cols),
                          jnp.asarray(bsr.block_nnz), jnp.asarray(x),
                          semiring=name, impl="ref")
        # append 2 extra all-padding tile slots per row
        pad_v = np.full((bsr.r, 2, 8, 8), z, np.float32)
        vals = np.concatenate([bsr.block_vals, pad_v], axis=1)
        cols = np.concatenate([bsr.block_cols,
                               np.zeros((bsr.r, 2), np.int32)], axis=1)
        y1 = ops.bsr_spmv(jnp.asarray(vals), jnp.asarray(cols),
                          jnp.asarray(bsr.block_nnz), jnp.asarray(x),
                          semiring=name, impl="ref")
        np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                                   rtol=1e-6)


def test_pallas_respects_nnz_bound(rng):
    """Garbage beyond block_nnz must not affect the Pallas result
    (self-timed execution: only true tiles are combined)."""
    g = G.rmat(60, 240, seed=4)
    bsr = G.to_bsr(g, b=8, pad_value=np.inf)  # min_plus
    vals = bsr.block_vals.copy()
    lane = np.arange(bsr.k_max)[None, :]
    trash = lane >= bsr.block_nnz[:, None]
    vals[np.broadcast_to(trash[:, :, None, None], vals.shape)] = -123.0
    x = rng.random((bsr.r, bsr.b)).astype(np.float32)
    y_pal = ops.bsr_spmv(jnp.asarray(vals), jnp.asarray(bsr.block_cols),
                         jnp.asarray(bsr.block_nnz), jnp.asarray(x),
                         semiring="min_plus", impl="pallas", bk=4)
    dense = _dense_spmv(G.bsr_to_dense(bsr), x.reshape(-1), "min_plus")
    np.testing.assert_allclose(np.asarray(y_pal).reshape(-1), dense,
                               rtol=1e-5, atol=1e-5)


_ = jax
