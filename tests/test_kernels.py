"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py
oracles vs dense numpy ground truth."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import graph as G
from repro.core import semiring as sr
from repro.kernels import ops, ref

SEMIRINGS = ["plus_times", "min_plus", "max_min", "min_select"]


def _dense_spmv(a, x, name):
    if name == "plus_times":
        return a @ x
    if name == "min_plus":
        return np.min(a + x[None, :], axis=1)
    if name == "max_min":
        return np.max(np.minimum(a, x[None, :]), axis=1)
    return np.min(np.where(np.isfinite(a), x[None, :], np.inf), axis=1)


@pytest.mark.parametrize("semiring", SEMIRINGS)
@pytest.mark.parametrize("n,e,b,bk", [(64, 256, 8, 2), (200, 800, 16, 4),
                                      (120, 900, 32, 8)])
def test_bsr_spmv_sweep(semiring, n, e, b, bk, rng):
    g = G.rmat(n, e, seed=n + e)
    bsr = G.to_bsr(g, b=b, pad_value=float(sr.get(semiring).zero))
    x = rng.random((bsr.r, bsr.b)).astype(np.float32)
    if semiring == "max_min":
        x = (x > 0.5).astype(np.float32)
    args = (jnp.asarray(bsr.block_vals), jnp.asarray(bsr.block_cols),
            jnp.asarray(bsr.block_nnz), jnp.asarray(x))
    y_ref = ops.bsr_spmv(*args, semiring=semiring, impl="ref")
    y_pal = ops.bsr_spmv(*args, semiring=semiring, impl="pallas", bk=bk)
    dense = _dense_spmv(G.bsr_to_dense(bsr), x.reshape(-1), semiring)
    np.testing.assert_allclose(np.asarray(y_ref).reshape(-1), dense,
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(y_pal).reshape(-1), dense,
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, None), (False, None),
                                           (True, 64)])
@pytest.mark.parametrize("b,h,kv,s,d", [(2, 4, 2, 256, 64),
                                        (1, 2, 1, 128, 32)])
def test_flash_attention_sweep(dtype, causal, window, b, h, kv, s, d, rng):
    q = jnp.asarray(rng.standard_normal((b, h, s, d)), dtype)
    k = jnp.asarray(rng.standard_normal((b, kv, s, d)), dtype)
    v = jnp.asarray(rng.standard_normal((b, kv, s, d)), dtype)
    o_ref = ops.attention(q, k, v, causal=causal, window=window,
                          impl="ref")
    o_pal = ops.attention(q, k, v, causal=causal, window=window,
                          impl="pallas", bq=64, bk=64)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(o_ref, np.float32),
                               np.asarray(o_pal, np.float32),
                               rtol=tol, atol=tol)


def test_chunked_attention_matches_exact(rng):
    b, h, s, d = 1, 2, 2048, 32
    q = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
    exact = ref.mha_ref(q, k, v, causal=True)
    chunk = ref.mha_chunked(q, k, v, causal=True, q_chunk=256)
    np.testing.assert_allclose(np.asarray(exact), np.asarray(chunk),
                               rtol=2e-5, atol=2e-5)


def test_bsr_padding_is_noop(rng):
    """Padding tiles hold ⊕-identities: adding empty tiles never changes
    the result (the kernel's 'empty FIFO slot' invariant)."""
    g = G.rmat(50, 200, seed=3)
    for name in SEMIRINGS:
        z = float(sr.get(name).zero)
        bsr = G.to_bsr(g, b=8, pad_value=z)
        x = rng.random((bsr.r, bsr.b)).astype(np.float32)
        y0 = ops.bsr_spmv(jnp.asarray(bsr.block_vals),
                          jnp.asarray(bsr.block_cols),
                          jnp.asarray(bsr.block_nnz), jnp.asarray(x),
                          semiring=name, impl="ref")
        # append 2 extra all-padding tile slots per row
        pad_v = np.full((bsr.r, 2, 8, 8), z, np.float32)
        vals = np.concatenate([bsr.block_vals, pad_v], axis=1)
        cols = np.concatenate([bsr.block_cols,
                               np.zeros((bsr.r, 2), np.int32)], axis=1)
        y1 = ops.bsr_spmv(jnp.asarray(vals), jnp.asarray(cols),
                          jnp.asarray(bsr.block_nnz), jnp.asarray(x),
                          semiring=name, impl="ref")
        np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                                   rtol=1e-6)


@pytest.mark.parametrize("rows_per_step", [2, 4])
def test_rows_per_step_matches_single_row(rows_per_step, rng):
    """Grid coarsening only regroups row-blocks per step — each row's
    accumulation order is untouched, so the result is unchanged."""
    g = G.rmat(100, 500, seed=9)
    for name in SEMIRINGS:
        bsr = G.to_bsr(g, b=8, pad_value=float(sr.get(name).zero))
        x = rng.random((bsr.r, bsr.b)).astype(np.float32)
        from repro.kernels.spec import KernelSpec
        args = (jnp.asarray(bsr.block_vals), jnp.asarray(bsr.block_cols),
                jnp.asarray(bsr.block_nnz), jnp.asarray(x))
        y1 = ops.bsr_spmv(*args, semiring=name, impl="pallas", bk=4)
        spmv = ops.select_kernel("bsr_spmv", KernelSpec(
            impl="pallas", block_size=4, rows_per_step=rows_per_step))
        yr = spmv(*args, semiring=name)
        np.testing.assert_array_equal(np.asarray(y1), np.asarray(yr))


# -- fused relax + frontier-select + convergence-reduce ---------------------

def _fused_oracle(bsr, x, valid, act, semiring, apply_kind="relax",
                  damping=0.85, tol=1e-6, inv_n=1e-2):
    """Unfused reference composition: ref SpMV -> engine apply rule ->
    frontier mask.  Rows outside ``act`` pass through bitwise."""
    from repro.core import semiring as S
    from repro.core.engine import _apply
    y = ops.bsr_spmv(jnp.asarray(bsr.block_vals),
                     jnp.asarray(bsr.block_cols),
                     jnp.asarray(bsr.block_nnz), jnp.asarray(x),
                     semiring=semiring, impl="ref")
    x_new, imp = _apply(apply_kind, S.get(semiring), y, jnp.asarray(x),
                        jnp.asarray(valid), jnp.float32(damping),
                        jnp.float32(inv_n), jnp.float32(tol))
    x_exp = np.where(act[:, None], np.asarray(x_new), x)
    ch_exp = act & np.any(np.asarray(imp), axis=1)
    return x_exp, ch_exp


def _fused_call(bsr, x, valid, act, semiring, apply_kind="relax", bk=4,
                vals=None):
    from repro.kernels.bsr_spmv import bsr_spmv_fused
    xj = jnp.asarray(x)
    return bsr_spmv_fused(
        jnp.asarray(vals if vals is not None else bsr.block_vals),
        jnp.asarray(bsr.block_cols), jnp.asarray(bsr.block_nnz),
        xj, xj, jnp.asarray(valid), jnp.asarray(act),
        jnp.float32(0.85), jnp.float32(1e-6), jnp.float32(1e-2),
        semiring=semiring, apply_kind=apply_kind, bk=bk)


@pytest.mark.parametrize("semiring", SEMIRINGS)
@pytest.mark.parametrize("frontier", ["empty", "sparse", "dense"])
def test_fused_matches_unfused_composition(semiring, frontier, rng):
    """The fused kernel must equal ref-SpMV + engine apply + frontier
    mask: EXACT for the comparison semirings, float-accumulation
    tolerance for plus_times (different reduction grouping)."""
    g = G.rmat(120, 700, seed=11)
    bsr = G.to_bsr(g, b=8, pad_value=float(sr.get(semiring).zero))
    x = rng.random((bsr.r, bsr.b)).astype(np.float32)
    if semiring == "max_min":
        x = (x > 0.5).astype(np.float32)
    valid = np.ones((bsr.r, bsr.b), bool)
    act = {"empty": np.zeros(bsr.r, bool),
           "sparse": rng.random(bsr.r) < 0.15,
           "dense": np.ones(bsr.r, bool)}[frontier]
    x_exp, ch_exp = _fused_oracle(bsr, x, valid, act, semiring)
    x_new, changed, conv = _fused_call(bsr, x, valid, act, semiring)
    if semiring == "plus_times":
        np.testing.assert_allclose(np.asarray(x_new), x_exp, rtol=2e-6)
    else:
        np.testing.assert_array_equal(np.asarray(x_new), x_exp)
    np.testing.assert_array_equal(np.asarray(changed), ch_exp)
    assert bool(conv) == bool(ch_exp.any())
    if frontier == "empty":
        # all-converged early exit: pure passthrough, nothing changed
        np.testing.assert_array_equal(np.asarray(x_new), x)
        assert not bool(conv)


def test_fused_pagerank_apply(rng):
    g = G.rmat(80, 400, seed=13)
    bsr = G.to_bsr(g, b=8, pad_value=0.0)
    x = rng.random((bsr.r, bsr.b)).astype(np.float32)
    valid = np.ones((bsr.r, bsr.b), bool)
    act = np.ones(bsr.r, bool)
    x_exp, ch_exp = _fused_oracle(bsr, x, valid, act, "plus_times",
                                  apply_kind="pagerank")
    x_new, changed, conv = _fused_call(bsr, x, valid, act, "plus_times",
                                       apply_kind="pagerank")
    np.testing.assert_allclose(np.asarray(x_new), x_exp, rtol=2e-6)
    np.testing.assert_array_equal(np.asarray(changed), ch_exp)


def test_fused_respects_nnz_bound(rng):
    """Garbage tiles beyond block_nnz must not leak into the fused
    result either (same self-timed bound as the unfused kernel)."""
    g = G.rmat(60, 240, seed=4)
    bsr = G.to_bsr(g, b=8, pad_value=np.inf)  # min_plus
    vals = bsr.block_vals.copy()
    lane = np.arange(bsr.k_max)[None, :]
    trash = lane >= bsr.block_nnz[:, None]
    vals[np.broadcast_to(trash[:, :, None, None], vals.shape)] = -123.0
    x = rng.random((bsr.r, bsr.b)).astype(np.float32)
    valid = np.ones((bsr.r, bsr.b), bool)
    act = np.ones(bsr.r, bool)
    x_exp, ch_exp = _fused_oracle(bsr, x, valid, act, "min_plus")
    x_new, changed, _ = _fused_call(bsr, x, valid, act, "min_plus",
                                    vals=vals)
    np.testing.assert_array_equal(np.asarray(x_new), x_exp)
    np.testing.assert_array_equal(np.asarray(changed), ch_exp)


def test_pallas_respects_nnz_bound(rng):
    """Garbage beyond block_nnz must not affect the Pallas result
    (self-timed execution: only true tiles are combined)."""
    g = G.rmat(60, 240, seed=4)
    bsr = G.to_bsr(g, b=8, pad_value=np.inf)  # min_plus
    vals = bsr.block_vals.copy()
    lane = np.arange(bsr.k_max)[None, :]
    trash = lane >= bsr.block_nnz[:, None]
    vals[np.broadcast_to(trash[:, :, None, None], vals.shape)] = -123.0
    x = rng.random((bsr.r, bsr.b)).astype(np.float32)
    y_pal = ops.bsr_spmv(jnp.asarray(vals), jnp.asarray(bsr.block_cols),
                         jnp.asarray(bsr.block_nnz), jnp.asarray(x),
                         semiring="min_plus", impl="pallas", bk=4)
    dense = _dense_spmv(G.bsr_to_dense(bsr), x.reshape(-1), "min_plus")
    np.testing.assert_allclose(np.asarray(y_pal).reshape(-1), dense,
                               rtol=1e-5, atol=1e-5)


_ = jax
