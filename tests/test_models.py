"""Per-architecture smoke tests (reduced configs): one forward + one
train step on CPU, asserting shapes and finiteness; plus prefill/decode
consistency against the teacher-forced forward."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import lm
from repro.train.optimizer import AdamW, warmup_cosine
from repro.train.step import make_train_step


def _batch(cfg, rng, b, s, train=True):
    out = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)}
    if train:
        out["labels"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
        out["loss_mask"] = jnp.ones((b, s), jnp.float32)
    if cfg.img_seq:
        out["img_embeds"] = jnp.asarray(
            rng.standard_normal((b, cfg.img_seq, cfg.d_model)),
            jnp.float32)
    if cfg.encdec:
        out["enc_embeds"] = jnp.asarray(
            rng.standard_normal((b, cfg.encoder_seq, cfg.d_model)),
            jnp.float32)
    return out


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch, rng):
    cfg = get_config(arch).reduced()
    params, axes = lm.init(cfg, jax.random.PRNGKey(0))
    assert jax.tree.structure(params) == jax.tree.structure(axes)
    b, s = 2, 32
    batch = _batch(cfg, rng, b, s)
    logits, aux = jax.jit(
        lambda p, bt: lm.forward_train(cfg, p, bt))(params, batch)
    assert logits.shape == (b, s, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    opt = AdamW(lr=warmup_cosine(1e-3, 2, 10))
    step = jax.jit(make_train_step(cfg, opt))
    p2, st2, metrics = step(params, opt.init(params), batch)
    assert np.isfinite(float(metrics["loss"]))
    # params actually moved
    delta = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                      - b_.astype(jnp.float32))))
                for a, b_ in zip(jax.tree.leaves(params),
                                 jax.tree.leaves(p2)))
    assert delta > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_consistency(arch, rng):
    cfg = dataclasses.replace(get_config(arch).reduced(),
                              compute_dtype="float32", remat=False,
                              capacity_factor=64.0)
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    b, s, extra = 2, 16, 3
    batch_full = _batch(cfg, rng, b, s + extra, train=False)
    batch_pre = dict(batch_full, tokens=batch_full["tokens"][:, :s])
    logits_full, _ = lm.forward_train(cfg, params, batch_full)
    lg, cache = lm.prefill(cfg, params, batch_pre, cache_len=s + extra)
    errs = [float(jnp.max(jnp.abs(lg - logits_full[:, s - 1])))]
    step = jax.jit(lambda p, c, t, pos: lm.decode_step(cfg, p, c, t, pos))
    toks = batch_full["tokens"]
    for i in range(extra):
        lg, cache = step(params, cache, toks[:, s + i], jnp.int32(s + i))
        errs.append(float(jnp.max(jnp.abs(lg - logits_full[:, s + i]))))
    assert max(errs) < 2e-3, errs


def test_param_count_matches_analytic():
    for arch in ARCH_IDS:
        cfg = get_config(arch).reduced()
        params, _ = lm.init(cfg, jax.random.PRNGKey(0))
        real = sum(x.size for x in jax.tree.leaves(params))
        est = cfg.param_count()
        # analytic estimate within 25% (norm scales / small lora terms)
        assert abs(real - est) / real < 0.25, (arch, real, est)


def test_full_config_param_counts():
    """Full configs land near their nameplate sizes."""
    expect = {"dbrx-132b": 132e9, "llama4-maverick-400b-a17b": 400e9,
              "granite-3-2b": 2.6e9, "chatglm3-6b": 6.2e9,
              "minicpm3-4b": 4.1e9, "nemotron-4-340b": 341e9,
              "rwkv6-1.6b": 1.6e9, "llama-3.2-vision-11b": 10.7e9,
              "whisper-tiny": 39e6, "recurrentgemma-9b": 9.6e9}
    for arch, tgt in expect.items():
        n = get_config(arch).param_count()
        assert abs(n - tgt) / tgt < 0.35, (arch, n, tgt)
