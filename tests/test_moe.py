"""MoE dispatch/combine (the paper's scatter/gather) vs a dense oracle."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import layers, moe


def _cfg(**kw):
    base = get_config("dbrx-132b").reduced()
    return dataclasses.replace(base, compute_dtype="float32", **kw)


def _dense_oracle(cfg, p, x):
    """Compute ALL experts densely, weight by normalized top-k gates."""
    logits = jnp.einsum("bsd,de->bse", x, p["router"])
    gates = jax.nn.softmax(logits, -1)
    topw, topi = jax.lax.top_k(gates, cfg.top_k)
    topw = topw / topw.sum(-1, keepdims=True)
    outs = []
    for e in range(cfg.num_experts):
        pe = {k: v[e] for k, v in p["experts"].items()}
        h = x @ pe["wi"]
        if cfg.mlp_kind == "swiglu":
            h = jax.nn.silu(x @ pe["wg"]) * h
        outs.append(h @ pe["wo"])
    dense = jnp.stack(outs, axis=2)  # (B,S,E,D)
    w = jnp.zeros(gates.shape).at[
        jnp.arange(x.shape[0])[:, None, None],
        jnp.arange(x.shape[1])[None, :, None], topi].add(topw)
    out = jnp.einsum("bse,bsed->bsd", w, dense)
    if cfg.shared_expert:
        out = out + layers.mlp_apply(cfg, p["shared"], x)
    return out


def test_moe_matches_dense_oracle_dropless(rng):
    cfg = _cfg(capacity_factor=64.0)  # effectively dropless
    p, _ = moe.moe_init(cfg, jax.random.PRNGKey(0))
    x = jnp.asarray(rng.standard_normal((2, 32, cfg.d_model)), jnp.float32)
    got, aux = moe.moe_apply(cfg, p, x)
    want = _dense_oracle(cfg, p, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    assert float(aux["frac_dropped"]) == 0.0


def test_moe_dropless_flag(rng):
    cfg = _cfg(capacity_factor=0.1)  # brutal capacity
    p, _ = moe.moe_init(cfg, jax.random.PRNGKey(0))
    x = jnp.asarray(rng.standard_normal((2, 32, cfg.d_model)), jnp.float32)
    _, aux_drop = moe.moe_apply(cfg, p, x)
    got, aux = moe.moe_apply(cfg, p, x, dropless=True)
    assert float(aux_drop["frac_dropped"]) > 0.0
    assert float(aux["frac_dropped"]) == 0.0
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(_dense_oracle(cfg, p, x)),
                               rtol=2e-4, atol=2e-4)


def test_moe_dropped_tokens_pass_residual_zero(rng):
    """Capacity-dropped tokens contribute zero (residual passthrough
    happens at the block level)."""
    cfg = _cfg(capacity_factor=0.01)
    p, _ = moe.moe_init(cfg, jax.random.PRNGKey(1))
    x = jnp.asarray(rng.standard_normal((1, 16, cfg.d_model)), jnp.float32)
    out, aux = moe.moe_apply(cfg, p, x)
    assert float(aux["frac_dropped"]) > 0.5
    assert bool(jnp.all(jnp.isfinite(out)))


def test_router_aux_loss_prefers_balance():
    cfg = _cfg()
    e = cfg.num_experts
    # aux = e·Σ(mean_gates · assign_frac): balanced (both uniform) → 1,
    # collapsed (both one-hot) → e
    u = jnp.full((e,), 1.0 / e)
    oh = jax.nn.one_hot(0, e)
    balanced = e * jnp.sum(u * u)
    collapsed = e * jnp.sum(oh * oh)
    assert float(balanced) < float(collapsed)


def test_moe_grads_flow(rng):
    cfg = _cfg(capacity_factor=2.0)
    p, _ = moe.moe_init(cfg, jax.random.PRNGKey(0))
    x = jnp.asarray(rng.standard_normal((2, 32, cfg.d_model)), jnp.float32)

    def loss(p):
        out, aux = moe.moe_apply(cfg, p, x)
        return jnp.sum(out ** 2) + aux["aux_loss"]

    g = jax.grad(loss)(p)
    gn = sum(float(jnp.sum(jnp.abs(t))) for t in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0
    # router must receive gradient through the aux loss
    assert float(jnp.sum(jnp.abs(g["router"]))) > 0
