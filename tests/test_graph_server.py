"""GraphServer: the continuous-batching network front door — wave
scheduler, deadlines, admission control, plan warming, asyncio adapter.

The load-bearing invariant everywhere: results delivered through the
server's futures are BIT-identical to direct ``GraphService.run`` calls,
including under concurrent multi-threaded submission."""

import threading
import time

import numpy as np
import pytest

from repro import api
from repro.core import engine as eng
from repro.core import graph as G
from repro.core import oracles as O


@pytest.fixture(scope="module")
def road():
    return G.road_network(10, seed=1)


@pytest.fixture()
def svc(road):
    svc = api.GraphService()
    svc.register("roads", road, b=16, num_clusters=8)
    return svc


def paused(svc, **wave_kw):
    """Server with the scheduler paused: submits accumulate, start()
    then closes deterministic waves (no timing races in assertions)."""
    wave = api.WavePolicy(**{"max_wait_s": 0.005, **wave_kw})
    return api.GraphServer(service=svc, wave=wave, autostart=False)


def sssp(s):
    return api.QuerySpec(algo="sssp", sources=(s,))


# ---------------------------------------------------------------------------
# correctness: futures == direct runs
# ---------------------------------------------------------------------------


def test_live_server_results_bit_identical_to_direct_run(svc):
    with api.GraphServer(service=svc) as server:
        futs = {s: server.submit("roads", sssp(s)) for s in (0, 3, 7)}
        f_pr = server.submit("roads", api.QuerySpec(algo="pagerank"))
        for s, f in futs.items():
            solo = svc.run("roads", sssp(s))
            np.testing.assert_array_equal(f.result(60).values,
                                          solo.values)
        np.testing.assert_array_equal(
            f_pr.result(60).values,
            svc.run("roads", api.QuerySpec(algo="pagerank")).values)


def test_concurrent_clients_bit_identical_and_waves_batch(svc):
    """N client threads submit into one server; every per-request
    result is bit-identical to sequential GraphService.run, and the
    scheduler's stats prove the waves actually batched (size > 1)."""
    server = paused(svc, max_wave=8)
    sources = list(range(16))
    futs = {}
    lock = threading.Lock()
    barrier = threading.Barrier(4)

    def client(chunk):
        barrier.wait()
        for s in chunk:
            f = server.submit("roads", sssp(s))
            with lock:
                futs[s] = f

    threads = [threading.Thread(target=client, args=(sources[i::4],))
               for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert server.sched.pending() == len(sources)
    server.start()
    for s in sources:
        solo = svc.run("roads", sssp(s))
        np.testing.assert_array_equal(futs[s].result(120).values,
                                      solo.values)
        assert futs[s].result().extra["src"] == s
    st = server.stats()["scheduler"]
    assert st["completed"] == len(sources)
    assert st["waves"] == 2 and st["max_wave"] == 8    # 16 = 2 × 8
    assert st["achieved_wave"] > 1.0
    assert st["coalesced_waves"] == 2
    server.close()


def test_scheduler_coalesces_across_submits_in_wait_window(svc):
    """A live scheduler holds a wave open for max_wait_s: requests
    submitted within the window share one batched dispatch."""
    server = api.GraphServer(service=svc,
                             wave=api.WavePolicy(max_wait_s=1.0,
                                                 max_wave=64))
    futs = [server.submit("roads", sssp(s)) for s in (0, 3, 7)]
    for f, s in zip(futs, (0, 3, 7)):
        np.testing.assert_array_equal(
            f.result(120).values, svc.run("roads", sssp(s)).values)
    st = server.stats()["scheduler"]
    assert st["max_wave"] >= 2   # at least two rode one wave
    server.close()


def test_wave_chunks_respect_max_wave(svc):
    server = paused(svc, max_wave=2)
    futs = [server.submit("roads", sssp(s)) for s in range(5)]
    server.start()
    for s, f in enumerate(futs):
        np.testing.assert_array_equal(
            f.result(120).values, svc.run("roads", sssp(s)).values)
    st = server.stats()["scheduler"]
    assert st["waves"] == 3                            # 2 + 2 + 1
    assert st["max_wave"] == 2
    server.close()


def test_mixed_algorithms_route_like_gather(svc):
    """Coalescible (sssp/bfs) and solo (pagerank/cc) requests in one
    stream: same grouping the gather() front door would produce."""
    server = paused(svc, max_wave=8)
    f_s = [server.submit("roads", sssp(s)) for s in (0, 5)]
    f_b = [server.submit("roads", api.QuerySpec(algo="bfs",
                                                sources=(s,)))
           for s in (0, 9)]
    f_cc = server.submit("roads", api.QuerySpec(algo="cc"))
    server.start()
    for s, f in zip((0, 5), f_s):
        np.testing.assert_array_equal(
            f.result(120).values, svc.run("roads", sssp(s)).values)
        assert f.result().extra["coalesced"] == 2
    for s, f in zip((0, 9), f_b):
        np.testing.assert_array_equal(
            f.result(120).values,
            svc.run("roads",
                    api.QuerySpec(algo="bfs", sources=(s,))).values)
    np.testing.assert_array_equal(
        f_cc.result(120).values,
        svc.run("roads", api.QuerySpec(algo="cc")).values)
    server.close()


# ---------------------------------------------------------------------------
# fail-fast submit
# ---------------------------------------------------------------------------


def test_submit_unknown_graph_raises_at_submit(svc):
    server = paused(svc)
    with pytest.raises(KeyError, match="no graph registered"):
        server.submit("ghost", sssp(0))
    with pytest.raises(ValueError, match="source"):
        server.submit("roads", api.QuerySpec(algo="sssp"))
    assert server.sched.pending() == 0
    server.close()


def test_submit_after_close_is_refused(svc):
    server = paused(svc)
    server.close()
    with pytest.raises(RuntimeError, match="closed"):
        server.submit("roads", sssp(0))


# ---------------------------------------------------------------------------
# deadlines
# ---------------------------------------------------------------------------


def test_expired_request_resolves_deadline_exceeded_not_in_wave(svc):
    server = paused(svc, max_wave=8)
    f_dead = server.submit("roads", sssp(0), deadline=0.0)
    f_live = server.submit("roads", sssp(3), deadline=120.0)
    time.sleep(0.01)
    server.start()
    with pytest.raises(api.DeadlineExceeded):
        f_dead.result(120)
    np.testing.assert_array_equal(
        f_live.result(120).values, svc.run("roads", sssp(3)).values)
    st = server.stats()["scheduler"]
    assert st["expired"] == 1
    assert st["wave_queries"] == 1       # the dead one never rode
    server.close()


def test_deadline_exceeded_is_a_timeout_error(svc):
    assert issubclass(api.DeadlineExceeded, TimeoutError)


def test_cancelled_future_never_occupies_a_wave_row(svc):
    """Future.cancel() before the wave closes drops the request from its
    pending group (ROADMAP PR-6 follow-up): the wave that runs is one
    row smaller and the scheduler counts the cancellation."""
    server = paused(svc, max_wave=8)
    futs = [server.submit("roads", sssp(s)) for s in (0, 3, 7)]
    assert futs[1].cancel()                 # still queued → cancellable
    assert server.sched.pending() == 3      # purge happens at wave close
    server.start()
    assert server.sched.drain(timeout=120)
    for f, s in ((futs[0], 0), (futs[2], 7)):
        np.testing.assert_array_equal(
            f.result(120).values, svc.run("roads", sssp(s)).values)
    assert futs[1].cancelled()
    st = server.stats()["scheduler"]
    assert st["cancelled"] == 1
    assert st["completed"] == 2
    assert st["wave_queries"] == 2          # the cancelled row never rode
    assert st["max_wave"] == 2
    server.close()


def test_cancel_after_dispatch_is_refused(svc):
    """Once a wave closed and began running, cancel() loses the race —
    the future still delivers its result (Future semantics: cancel only
    succeeds before set_running_or_notify_cancel)."""
    with api.GraphServer(service=svc) as server:
        f = server.submit("roads", sssp(0))
        f.result(120)                       # already ran to completion
        assert not f.cancel()
        np.testing.assert_array_equal(
            f.result().values, svc.run("roads", sssp(0)).values)


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------


def test_backpressure_on_full_pending_queue(svc):
    server = paused(svc, max_pending=2)
    f = [server.submit("roads", sssp(s)) for s in (0, 3)]
    with pytest.raises(api.Backpressure) as exc:
        server.submit("roads", sssp(7))
    assert exc.value.stats["scheduler"]["pending"] == 2
    assert server.stats()["server"]["rejected_pending"] == 1
    server.start()
    for s, fut in zip((0, 3), f):
        np.testing.assert_array_equal(
            fut.result(120).values, svc.run("roads", sssp(s)).values)
    server.sched.drain(timeout=120)
    server.submit("roads", sssp(7)).result(120)   # admitted again
    server.close()


def test_backpressure_on_plan_store_thrash(svc):
    server = paused(svc, thrash_evictions=3, thrash_window_s=60.0)
    server.submit("roads", sssp(0))              # takes a sample at 0
    svc.store._stats["evictions"] += 3           # store starts churning
    with pytest.raises(api.Backpressure, match="thrash"):
        server.submit("roads", sssp(3))
    assert server.stats()["server"]["rejected_thrash"] == 1
    server.close()


# ---------------------------------------------------------------------------
# eviction + shutdown semantics
# ---------------------------------------------------------------------------


def test_evict_resolves_queued_requests(svc):
    svc.register("keep", G.road_network(6, seed=3), b=16,
                 num_clusters=4)
    server = paused(svc)
    f_gone = server.submit("roads", sssp(0))
    f_kept = server.submit("keep", sssp(0))
    server.evict("roads")
    with pytest.raises(KeyError, match="evicted"):
        f_gone.result(120)
    server.start()
    assert f_kept.result(120).stats.converged
    server.close()


def test_close_drains_pending_requests(svc):
    server = paused(svc)                 # scheduler never started
    futs = [server.submit("roads", sssp(s)) for s in (0, 3)]
    server.close()                       # drain=True completes them
    for s, f in zip((0, 3), futs):
        np.testing.assert_array_equal(
            f.result(0).values, svc.run("roads", sssp(s)).values)


def test_close_without_drain_fails_queue_with_backpressure(svc):
    server = paused(svc)
    fut = server.submit("roads", sssp(0))
    server.close(drain=False)
    with pytest.raises(api.Backpressure):
        fut.result(0)


def test_runtime_failure_isolated_per_future(svc, monkeypatch):
    proc = svc.get("roads")
    real_run = proc.run

    def flaky(spec):
        if spec.algo == "cc":
            raise RuntimeError("engine fell over")
        return real_run(spec)

    monkeypatch.setattr(proc, "run", flaky)
    server = paused(svc)
    f_bad = server.submit("roads", api.QuerySpec(algo="cc"))
    f_ok = server.submit("roads", sssp(0))
    server.start()
    with pytest.raises(RuntimeError, match="fell over"):
        f_bad.result(120)
    assert f_ok.result(120).stats.converged
    assert server.stats()["scheduler"]["failed"] == 1
    server.close()


# ---------------------------------------------------------------------------
# plan warming
# ---------------------------------------------------------------------------


def test_register_warms_hot_plans_from_access_log(road, tmp_path,
                                                  monkeypatch):
    cache = str(tmp_path / "plans")
    s1 = api.GraphServer(cache_dir=cache)
    s1.register("roads", road, b=16, num_clusters=8)
    s1.run("roads", sssp(0))                       # min_plus is hot
    s1.run("roads", api.QuerySpec(algo="pagerank"))  # plus_times too
    s1.close()                                     # flushes access log

    s2 = api.GraphServer(cache_dir=cache)
    proc2 = s2.register("roads", road, b=16, num_clusters=8)
    assert s2.wait_warm(timeout=120)
    assert s2.stats()["server"]["plans_warmed"] == 2

    # the compile pipeline must NOT run to serve the warmed plans
    def boom(*a, **kw):
        raise AssertionError("compile pipeline ran after warming")

    monkeypatch.setattr(eng, "prepare", boom)
    r = s2.run("roads", sssp(0))
    assert proc2._prepare_calls == 0
    np.testing.assert_allclose(r.values, O.sssp_oracle(road, 0),
                               rtol=1e-5, atol=1e-4)
    s2.close()


def test_warming_skips_keys_with_foreign_session_parameters(road,
                                                            tmp_path):
    cache = str(tmp_path / "plans")
    s1 = api.GraphServer(cache_dir=cache)
    s1.register("roads", road, b=16, num_clusters=8)
    s1.run("roads", sssp(0))
    s1.close()
    s2 = api.GraphServer(cache_dir=cache)
    s2.register("roads", road, b=8, num_clusters=4)   # different tiling
    assert s2.wait_warm(timeout=120)
    assert s2.stats()["server"]["plans_warmed"] == 0
    s2.close()


def test_warm_limit_and_opt_out(road, tmp_path):
    cache = str(tmp_path / "plans")
    s1 = api.GraphServer(cache_dir=cache)
    s1.register("roads", road, b=16, num_clusters=8)
    s1.run("roads", sssp(0))
    s1.close()
    s2 = api.GraphServer(cache_dir=cache)
    s2.register("roads", road, b=16, num_clusters=8, warm=False)
    assert s2.wait_warm(timeout=120)
    assert s2.stats()["server"]["plans_warmed"] == 0
    s2.close()


def test_hot_keys_orders_by_access_count(road, tmp_path):
    store = api.PlanStore(cache_dir=str(tmp_path))
    proc = api.GraphProcessor(road, b=16, num_clusters=8, store=store)
    proc.prepare("min_plus")
    for _ in range(3):
        proc.prepare("plus_times", normalize="out_stochastic")
    hot = store.hot_keys(road.fingerprint())
    assert [k.semiring for k in hot] == ["plus_times", "min_plus"]
    assert store.hot_keys(road.fingerprint(), limit=1) == hot[:1]
    # the log survives a "process restart"
    store.flush_access_log()
    again = api.PlanStore(cache_dir=str(tmp_path))
    assert again.hot_keys(road.fingerprint()) == hot


def test_corrupt_access_log_only_costs_warming(road, tmp_path):
    store = api.PlanStore(cache_dir=str(tmp_path))
    proc = api.GraphProcessor(road, b=16, num_clusters=8, store=store)
    proc.prepare("min_plus")
    store.flush_access_log()
    from repro.serve.graph import ACCESS_LOG
    (tmp_path / ACCESS_LOG).write_text("{not json")
    fresh = api.PlanStore(cache_dir=str(tmp_path))
    assert fresh.hot_keys(road.fingerprint()) == []   # no raise
    assert fresh.get(road.fingerprint(),
                     proc.plan_key("min_plus")) is not None  # disk tier ok


# ---------------------------------------------------------------------------
# asyncio adapter
# ---------------------------------------------------------------------------


def test_asyncio_adapter_serves_coroutines(svc):
    import asyncio

    server = paused(svc, max_wave=4)

    async def client():
        aws = [server.submit_async("roads", sssp(s)) for s in (0, 3, 7)]
        server.start()
        return await asyncio.gather(*aws)

    results = asyncio.run(client())
    for s, r in zip((0, 3, 7), results):
        np.testing.assert_array_equal(
            r.values, svc.run("roads", sssp(s)).values)
    assert server.stats()["scheduler"]["max_wave"] == 3
    server.close()


# ---------------------------------------------------------------------------
# WavePolicy validation
# ---------------------------------------------------------------------------


def test_wave_policy_validates_knobs():
    with pytest.raises(ValueError, match="max_wave"):
        api.WavePolicy(max_wave=0)
    with pytest.raises(ValueError, match="max_wait_s"):
        api.WavePolicy(max_wait_s=-1.0)
    with pytest.raises(ValueError, match="workers"):
        api.WavePolicy(workers=0)
    assert api.WavePolicy().but(max_wave=7).max_wave == 7
