"""ISA compilation + platform models."""

import numpy as np

from repro.core import algorithms as A
from repro.core import compile as GC
from repro.core import graph as G
from repro.core import isa
from repro.core import power as PW


def _prepared():
    g = G.rmat(300, 1500, seed=9)
    ra = A.sssp(g, 0, mode="async", b=16, num_clusters=8)
    rs = A.sssp(g, 0, mode="sync", b=16, num_clusters=8)
    return g, ra, rs


def test_compile_emits_program_per_cluster():
    g, ra, _ = _prepared()
    p = ra.prepared
    prog = GC.compile_graph_program(p, "relax")
    assert len(prog.programs) == p.s
    assert prog.total_instructions() > p.s  # nontrivial
    # every nonempty cluster ends with a sweep boundary
    for pr in prog.programs:
        ops = pr.code[:, 0].tolist()
        assert ops[-1] == isa.OPCODES["GSYN"]
    # GMAC count equals true tile count
    total_gmac = sum(pr.histogram()["GMAC"] for pr in prog.programs)
    assert total_gmac == int(np.asarray(p.nnz).sum())


def test_disassemble_and_cycles():
    g, ra, _ = _prepared()
    prog = GC.compile_graph_program(ra.prepared, "relax")
    text = prog.programs[0].disassemble()
    assert "GCFG" in text
    assert (prog.static_cycles >= 1).all()


def test_platform_models_ordering():
    """NALE beats the in-order CPU; async NALE power ≪ GPU power —
    the paper's two headline directions."""
    g, ra, rs = _prepared()
    p = ra.prepared
    nale = PW.model_nale(p, ra.stats)
    cpu = PW.model_cpu(p, ra.stats)
    gpu = PW.model_gpu(p, rs.stats,
                       k_max_pad=float(np.diff(g.indptr).max()),
                       avg_degree=g.avg_degree)
    assert nale.time_s < cpu.time_s
    assert nale.power_w < gpu.power_w
    assert nale.perf_per_watt > gpu.perf_per_watt
    for r in (nale, cpu, gpu):
        assert r.cycles > 0 and r.energy_j > 0 and r.power_w > 0


def test_nale_scales_with_parallelism():
    g, ra, _ = _prepared()
    p = ra.prepared
    few = PW.model_nale(p, ra.stats, PW.NaleConfig(num_nales=2))
    many = PW.model_nale(p, ra.stats, PW.NaleConfig(num_nales=256))
    assert many.time_s <= few.time_s
