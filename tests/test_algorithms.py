"""The paper's six algorithms vs. reference oracles, on all three
workload families (road / power-law / ring), both engines."""

import numpy as np
import pytest

from repro.core import algorithms as A
from repro.core import graph as G
from repro.core import oracles as O

GRAPHS = {
    "road": lambda: G.road_network(14, seed=1),
    "rmat": lambda: G.rmat(250, 1200, seed=2),
    "ring": lambda: G.ring(64),
}


def _partition(labels):
    m = {}
    for i, l_ in enumerate(labels):
        m.setdefault(round(float(l_), 4), set()).add(i)
    return sorted(map(frozenset, m.values()))


@pytest.mark.parametrize("gname", list(GRAPHS))
@pytest.mark.parametrize("mode", ["sync", "async"])
def test_sssp(gname, mode):
    g = GRAPHS[gname]()
    r = A.sssp(g, 0, mode=mode, b=16, num_clusters=8)
    np.testing.assert_allclose(r.values, O.sssp_oracle(g, 0), rtol=1e-5,
                               atol=1e-4)
    assert r.stats.converged


@pytest.mark.parametrize("gname", list(GRAPHS))
@pytest.mark.parametrize("mode", ["sync", "async"])
def test_bfs(gname, mode):
    g = GRAPHS[gname]()
    r = A.bfs(g, 0, mode=mode, b=16, num_clusters=8)
    np.testing.assert_array_equal(r.values, O.bfs_oracle(g, 0))


@pytest.mark.parametrize("gname", ["road", "rmat"])
@pytest.mark.parametrize("mode", ["sync", "async"])
def test_pagerank(gname, mode):
    g = GRAPHS[gname]()
    r = A.pagerank(g, tol=1e-9, mode=mode, b=16, num_clusters=8)
    pr = O.pagerank_oracle(g, tol=1e-12)
    assert np.max(np.abs(r.values - pr)) < 1e-5
    assert abs(r.values.sum() - 1.0) < 1e-5


@pytest.mark.parametrize("gname", list(GRAPHS))
@pytest.mark.parametrize("mode", ["sync", "async"])
def test_connected_components(gname, mode):
    g = GRAPHS[gname]()
    r = A.connected_components(g, mode=mode, b=16, num_clusters=8)
    assert _partition(r.values) == _partition(O.cc_oracle(g))


@pytest.mark.parametrize("gname", ["road", "rmat"])
def test_minitri(gname):
    g = GRAPHS[gname]()
    r = A.minitri(g)
    assert r.extra["triangles"] == O.triangles_oracle(g)


@pytest.mark.parametrize("gname", list(GRAPHS))
def test_dfs(gname):
    g = GRAPHS[gname]()
    r = A.dfs(g, 0)
    order, parent = O.dfs_oracle(g, 0)
    nv = r.extra["visited_count"]
    assert nv == len(order)
    np.testing.assert_array_equal(r.values[:nv], order)
    np.testing.assert_array_equal(r.extra["parent"], parent)


def test_reachability():
    g = GRAPHS["rmat"]()
    r = A.reachability(g, 0, mode="sync", b=16, num_clusters=8)
    np.testing.assert_array_equal(r.values > 0,
                                  np.isfinite(O.bfs_oracle(g, 0)))


def test_async_beats_sync_on_road():
    """Paper claim (directional): data-driven execution does less work
    than bulk-synchronous on high-diameter graphs."""
    g = GRAPHS["road"]()
    ra = A.sssp(g, 0, mode="async", b=16, num_clusters=16)
    rs = A.sssp(g, 0, mode="sync", b=16, num_clusters=16)
    assert ra.stats.edge_work < rs.stats.edge_work
    assert ra.stats.sweeps <= rs.stats.sweeps


def test_clustering_improves_tile_density():
    g = G.rmat(400, 2000, seed=7)
    from repro.core.cluster import cluster_graph, tile_stats_after
    c = cluster_graph(g, 16)
    st = tile_stats_after(g, c, b=16)
    assert st["fill_clustered"] >= st["fill_identity"]
