"""The algorithm catalog vs. reference oracles.

Part 1: the paper's six algorithms on all three workload families
(road / power-law / ring), both local engines.

Part 2 (PR 9): the AlgorithmSpec registry — parity grid for the four
new families (pagerank_delta / cc / kcore / tricount) across every
engine flavor (sync × async × distributed sync/async × ref/fused
kernels), bit-identical where the update rule is exact and
tolerance-bounded for the accumulation family, plus regression tests
for registry-driven dispatch (custom semirings, construction-time
QuerySpec validation, the removed PageRank ValueError)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core import algorithms as A
from repro.core import graph as G
from repro.core import oracles as O
from repro.core import semiring as S

GRAPHS = {
    "road": lambda: G.road_network(14, seed=1),
    "rmat": lambda: G.rmat(250, 1200, seed=2),
    "ring": lambda: G.ring(64),
}


def _partition(labels):
    m = {}
    for i, l_ in enumerate(labels):
        m.setdefault(round(float(l_), 4), set()).add(i)
    return sorted(map(frozenset, m.values()))


@pytest.mark.parametrize("gname", list(GRAPHS))
@pytest.mark.parametrize("mode", ["sync", "async"])
def test_sssp(gname, mode):
    g = GRAPHS[gname]()
    r = A.sssp(g, 0, mode=mode, b=16, num_clusters=8)
    np.testing.assert_allclose(r.values, O.sssp_oracle(g, 0), rtol=1e-5,
                               atol=1e-4)
    assert r.stats.converged


@pytest.mark.parametrize("gname", list(GRAPHS))
@pytest.mark.parametrize("mode", ["sync", "async"])
def test_bfs(gname, mode):
    g = GRAPHS[gname]()
    r = A.bfs(g, 0, mode=mode, b=16, num_clusters=8)
    np.testing.assert_array_equal(r.values, O.bfs_oracle(g, 0))


@pytest.mark.parametrize("gname", ["road", "rmat"])
@pytest.mark.parametrize("mode", ["sync", "async"])
def test_pagerank(gname, mode):
    g = GRAPHS[gname]()
    r = A.pagerank(g, tol=1e-9, mode=mode, b=16, num_clusters=8)
    pr = O.pagerank_oracle(g, tol=1e-12)
    assert np.max(np.abs(r.values - pr)) < 1e-5
    assert abs(r.values.sum() - 1.0) < 1e-5


@pytest.mark.parametrize("gname", list(GRAPHS))
@pytest.mark.parametrize("mode", ["sync", "async"])
def test_connected_components(gname, mode):
    g = GRAPHS[gname]()
    r = A.connected_components(g, mode=mode, b=16, num_clusters=8)
    assert _partition(r.values) == _partition(O.cc_oracle(g))


@pytest.mark.parametrize("gname", ["road", "rmat"])
def test_minitri(gname):
    g = GRAPHS[gname]()
    r = A.minitri(g)
    assert r.extra["triangles"] == O.triangles_oracle(g)


@pytest.mark.parametrize("gname", list(GRAPHS))
def test_dfs(gname):
    g = GRAPHS[gname]()
    r = A.dfs(g, 0)
    order, parent = O.dfs_oracle(g, 0)
    nv = r.extra["visited_count"]
    assert nv == len(order)
    np.testing.assert_array_equal(r.values[:nv], order)
    np.testing.assert_array_equal(r.extra["parent"], parent)


def test_reachability():
    g = GRAPHS["rmat"]()
    r = A.reachability(g, 0, mode="sync", b=16, num_clusters=8)
    np.testing.assert_array_equal(r.values > 0,
                                  np.isfinite(O.bfs_oracle(g, 0)))


def test_async_beats_sync_on_road():
    """Paper claim (directional): data-driven execution does less work
    than bulk-synchronous on high-diameter graphs."""
    g = GRAPHS["road"]()
    ra = A.sssp(g, 0, mode="async", b=16, num_clusters=16)
    rs = A.sssp(g, 0, mode="sync", b=16, num_clusters=16)
    assert ra.stats.edge_work < rs.stats.edge_work
    assert ra.stats.sweeps <= rs.stats.sweeps


def test_clustering_improves_tile_density():
    g = G.rmat(400, 2000, seed=7)
    from repro.core.cluster import cluster_graph, tile_stats_after
    c = cluster_graph(g, 16)
    st = tile_stats_after(g, c, b=16)
    assert st["fill_clustered"] >= st["fill_identity"]


# ---------------------------------------------------------------------------
# PR 9 — parity grid: the four new families through every engine flavor
# ---------------------------------------------------------------------------

# Every engine flavor the relaxation path can run under.  Distributed
# flavors degrade gracefully to a 1×1 mesh on a single device and widen
# to real meshes under the DEVICES=8 CI lane.
FLAVORS = {
    "sync-ref": api.ExecutionPolicy(mode="sync"),
    "sync-fused": api.ExecutionPolicy(
        mode="sync",
        kernel=api.KernelSpec(impl="pallas", fuse_frontier=True)),
    "async-ref": api.ExecutionPolicy(mode="async"),
    "async-fused": api.ExecutionPolicy(
        mode="async",
        kernel=api.KernelSpec(impl="pallas", fuse_frontier=True)),
    "dist-sync": api.ExecutionPolicy(mode="distributed"),
    "dist-async": api.ExecutionPolicy(mode="distributed",
                                      dist_flavor="async", local_sweeps=2),
}

PARITY_GRAPHS = {
    "road": lambda: G.road_network(8, seed=1),
    "rmat": lambda: G.rmat(96, 520, seed=5),
}

_PROCS = {}


def _proc(gname):
    if gname not in _PROCS:
        _PROCS[gname] = api.GraphProcessor(PARITY_GRAPHS[gname](),
                                           b=16, num_clusters=8)
    return _PROCS[gname]


@pytest.mark.parametrize("flavor", list(FLAVORS))
@pytest.mark.parametrize("gname", list(PARITY_GRAPHS))
def test_pagerank_delta_parity(gname, flavor):
    """Delta-accumulating PageRank is flavor-eligible everywhere —
    including dist_flavor='async', which rejected classic pagerank —
    and lands within the tol/(1-d) accumulation bound of the oracle."""
    proc = _proc(gname)
    pol = FLAVORS[flavor].but(tol=1e-10, max_sweeps=3000)
    r = proc.pagerank_delta(policy=pol)
    pr = O.pagerank_oracle(proc.g, tol=1e-12)
    assert np.max(np.abs(np.asarray(r.values) - pr)) < 1e-5
    assert abs(float(np.asarray(r.values).sum()) - 1.0) < 1e-5
    assert r.stats.converged


@pytest.mark.parametrize("flavor", list(FLAVORS))
@pytest.mark.parametrize("gname", list(PARITY_GRAPHS))
def test_cc_parity(gname, flavor):
    """min_select label propagation is idempotent ⇒ every flavor lands
    on the identical fixpoint, bit-for-bit."""
    proc = _proc(gname)
    r = proc.run(api.QuerySpec(algo="cc", policy=FLAVORS[flavor]))
    baseline = proc.run(api.QuerySpec(algo="cc", policy=FLAVORS["sync-ref"]))
    np.testing.assert_array_equal(np.asarray(r.values),
                                  np.asarray(baseline.values))
    assert _partition(np.asarray(r.values)) == _partition(O.cc_oracle(proc.g))


@pytest.mark.parametrize("k", [2, 3])
@pytest.mark.parametrize("flavor", list(FLAVORS))
def test_kcore_parity(flavor, k):
    """k-core peeling is monotone-decreasing and exact: bit-identical
    membership across every flavor, equal to the numpy peeling oracle."""
    proc = _proc("rmat")
    r = proc.kcore(k, policy=FLAVORS[flavor])
    np.testing.assert_array_equal(np.asarray(r.values),
                                  O.kcore_oracle(proc.g, k))
    assert r.stats.converged


def test_kcore_isolated_vertices_die():
    """bias=True regression: rows with no undirected neighbors must be
    touched once so they leave the core (fused sweep-0 / async
    first-touch both honor UpdateRule.bias)."""
    g = G.rmat(64, 150, seed=9)
    proc = api.GraphProcessor(g, b=16, num_clusters=4)
    for flavor in ("sync-fused", "async-ref"):
        r = proc.kcore(1, policy=FLAVORS[flavor])
        np.testing.assert_array_equal(np.asarray(r.values),
                                      O.kcore_oracle(g, 1))


@pytest.mark.parametrize("gname", ["road", "rmat"])
def test_tricount(gname):
    """Per-vertex triangle counts: exact match against the dense
    oracle, and the global total agrees with minitri's."""
    proc = _proc(gname)
    r = proc.tricount()
    np.testing.assert_array_equal(np.asarray(r.values),
                                  O.tricount_oracle(proc.g))
    assert r.extra["triangles"] == O.triangles_oracle(proc.g)
    assert int(np.asarray(r.values).sum()) == 3 * r.extra["triangles"]


def test_tricount_free_function():
    g = PARITY_GRAPHS["rmat"]()
    r = A.tricount(g)
    assert r.extra["triangles"] == O.triangles_oracle(g)


# ---------------------------------------------------------------------------
# PR 9 — registry-driven dispatch regressions
# ---------------------------------------------------------------------------


def test_classic_pagerank_still_rejected_by_async_dist():
    """The order-sensitive accumulation rule stays ineligible for the
    self-timed distributed schedule; the error now names the delta form."""
    proc = _proc("rmat")
    pol = api.ExecutionPolicy(mode="distributed", dist_flavor="async",
                              local_sweeps=2)
    with pytest.raises(ValueError, match="pagerank_delta"):
        proc.run(api.QuerySpec(algo="pagerank", policy=pol))


def test_unknown_algorithm_fails_at_construction():
    """QuerySpec validates against the registry at construction time and
    lists what is registered."""
    with pytest.raises(ValueError, match="unknown algorithm"):
        api.QuerySpec(algo="warp", sources=(0,))
    with pytest.raises(ValueError, match="pagerank_delta"):
        api.QuerySpec(algo="warp", sources=(0,))


def test_kcore_requires_k():
    proc = _proc("rmat")
    with pytest.raises(ValueError, match="requires params"):
        proc.run(api.QuerySpec(algo="kcore"))


def test_registry_introspection():
    names = api.registered_algorithms()
    for want in ("sssp", "bfs", "pagerank", "pagerank_delta", "cc",
                 "kcore", "tricount", "minitri", "reachability", "dfs"):
        assert want in names
    spec = api.get_algorithm("pagerank_delta")
    assert spec.semiring == "plus_times"
    assert S.rule(spec.update).monotone
    assert not S.rule("pagerank").monotone
    with pytest.raises(ValueError, match="unknown algorithm"):
        api.get_algorithm("warp")


# ---------------------------------------------------------------------------
# PR 9 — custom semirings: reduce() field + generic kernel fallback
# ---------------------------------------------------------------------------


def _max_times_ring():
    """Best-reliability ring over [0, 1] weights: ⊕ = max, ⊗ = ×.
    zero=0.0 absorbs under ⊗ (the register() contract)."""
    name = "test_max_times"
    if name not in S.SEMIRINGS:
        S.register(S.Semiring(
            name=name,
            add=jnp.maximum,
            mul=jnp.multiply,
            zero=0.0,
            one=1.0,
            improves=lambda new, old: new > old,
            reduce_fn=lambda x, axis=None: jnp.max(x, axis=axis),
        ))
    return S.get(name)


def test_custom_semiring_reduce_is_a_field():
    """Satellite 1: Semiring.reduce dispatches through the dataclass
    field (or the generic ⊕-fold), not a name switch — a freshly
    registered ring must reduce without touching builtin names."""
    ring = _max_times_ring()
    x = jnp.asarray(np.random.default_rng(0).random((3, 4, 5)),
                    dtype=jnp.float32)
    np.testing.assert_allclose(ring.reduce(x, axis=(0, 2)),
                               np.max(np.asarray(x), axis=(0, 2)))
    # a ring registered with reduce_fn=None gets the generic ⊕-fold
    noname = S.Semiring(name="test_fold", add=jnp.maximum, mul=jnp.multiply,
                        zero=0.0, one=1.0,
                        improves=lambda new, old: new > old)
    np.testing.assert_allclose(np.asarray(noname.reduce(x, axis=(1,))),
                               np.max(np.asarray(x), axis=1), rtol=1e-6)
    np.testing.assert_allclose(float(noname.reduce(x)),
                               float(np.max(np.asarray(x))), rtol=1e-6)


def test_custom_semiring_ref_kernel_fallback():
    """bsr_spmv_ref must handle any registered ring via the generic
    ⊗-then-⊕ path (it used to raise ValueError off the builtin list)."""
    from repro.kernels.ref import bsr_spmv_ref
    ring = _max_times_ring()
    rng = np.random.default_rng(3)
    r_, k_, b_, c_ = 3, 2, 4, 5
    vals = rng.random((r_, k_, b_, b_)).astype(np.float32)
    cols = rng.integers(0, c_, size=(r_, k_)).astype(np.int32)
    x = rng.random((c_, b_)).astype(np.float32)
    y = np.asarray(bsr_spmv_ref(jnp.asarray(vals), jnp.asarray(cols),
                                jnp.asarray(x), semiring=ring.name))
    want = (vals * x[cols][:, :, None, :]).max(axis=(1, 3))
    np.testing.assert_allclose(y, want, rtol=1e-6)


def test_custom_algorithm_end_to_end():
    """Registering a ring + AlgorithmSpec is all it takes to run through
    GraphProcessor.run — no engine/kernel edits (the tentpole claim)."""
    _max_times_ring()
    name = "test_reliability"
    if name not in api.registered_algorithms():
        api.register_algorithm(api.AlgorithmSpec(
            name=name,
            semiring="test_max_times",
            update="relax",
            variant="base",
            source_required=True,
            init=lambda p, src, pol: np.where(
                np.arange(p.n) == src, 1.0, 0.0).astype(np.float32),
            default_policy=(("max_sweeps", 10_000),),
        ))
    g = G.rmat(80, 400, seed=11)
    # squash weights into (0, 1] so products are path reliabilities
    g = G.Graph(n=g.n, indptr=g.indptr, indices=g.indices,
                weights=(1.0 / (1.0 + g.weights)).astype(np.float32))

    def oracle(g, src):
        x = np.zeros(g.n, dtype=np.float64)
        x[src] = 1.0
        srcs = np.repeat(np.arange(g.n), np.diff(g.indptr))
        for _ in range(g.n):
            cand = x[srcs] * g.weights
            x_new = x.copy()
            np.maximum.at(x_new, g.indices, cand)
            if np.array_equal(x_new, x):
                break
            x = x_new
        return x.astype(np.float32)

    proc = api.GraphProcessor(g, b=16, num_clusters=8)
    for mode in ("sync", "async"):
        r = proc.run(api.QuerySpec(algo=name, sources=(0,),
                                   policy=api.ExecutionPolicy(mode=mode)))
        np.testing.assert_allclose(np.asarray(r.values), oracle(g, 0),
                                   rtol=1e-5, atol=1e-6)
