"""Self-timed asynchronous distributed engine (core/async_dist.py).

The contract under test: ``dist_flavor="async"`` reaches the SAME
fixpoint as the bulk-synchronous distributed engine — bit-identical
converged state on every mesh factorization and every k — while
``DistStats.halo_exchanges`` strictly drops for k > 1 on multi-sweep
fixpoints.  Multi-mesh cases run in-process on the DEVICES=8 CI lane
(fake host devices) and fall back to one subprocess sweep elsewhere,
mirroring tests/test_distribution.py.
"""

import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro import api
from repro.core import async_dist as AD
from repro.core import engine as eng
from repro.core import graph as G
from repro.core import placement as PL

# (num_devices, query_axis) — the factorizations the issue names
FACTORIZATIONS = [(1, 1), (4, 2), (8, 1), (8, 8)]
KS = [1, 2, 4]


def _batched_fixture(semiring):
    """(Prepared, stacked x0, sync-batched reference) for one semiring."""
    g = G.rmat(200, 900, seed=6)
    sources = [0, 5, 9, 13, 17]
    p = eng.prepare(g, semiring, b=8, num_clusters=8)
    if semiring == "max_min":
        def x0f(s):
            x = np.zeros(g.n, dtype=np.float32)
            x[s] = 1.0
            return np.asarray(p.to_blocks(x, 0.0))
    else:
        def x0f(s):
            x = np.full(g.n, np.inf, dtype=np.float32)
            x[s] = 0.0
            return np.asarray(p.to_blocks(x, np.inf))
    x0 = np.stack([x0f(s) for s in sources])
    ref, _ = eng.run_sync_batched(p, x0, max_sweeps=100_000)
    return p, x0, np.asarray(ref)


# -- parity + exchange reduction ----------------------------------------


@pytest.mark.parametrize("semiring", ["min_plus", "max_min"])
@pytest.mark.parametrize("k", KS)
@pytest.mark.parametrize("ndev,qaxis", FACTORIZATIONS)
def test_async_parity_across_factorizations(semiring, k, ndev, qaxis):
    """Async == sync distributed == run_sync_batched, BIT-identical, on
    every factorization × k.  Needs the multi-device lane's fake-device
    grid for the non-trivial meshes."""
    if len(jax.devices()) < ndev:
        pytest.skip(f"needs {ndev} devices (CI multi-device lane); "
                    f"have {len(jax.devices())} — subprocess test "
                    "covers this elsewhere")
    p, x0, ref = _batched_fixture(semiring)
    mesh = PL.make_graph_mesh(ndev, qaxis)
    x, ds = AD.distributed_async_run_batched(
        p, x0, max_sweeps=100_000, mesh=mesh, local_sweeps=k)
    assert np.array_equal(np.asarray(x), ref)
    assert ds.converged
    assert ds.mesh_shape == (ndev // qaxis, qaxis)
    assert ds.local_sweeps == k
    assert ds.query_sweeps.shape == (x0.shape[0],)
    assert ds.sweeps == int(ds.query_sweeps.max())
    # per-shard self-timed sweep counters, one per "graph" shard
    assert ds.shard_sweeps.shape == (ndev // qaxis,)
    assert int(ds.shard_sweeps.max()) >= ds.sweeps


@pytest.mark.parametrize("semiring", ["min_plus", "max_min"])
def test_k_strictly_reduces_halo_exchanges(semiring):
    """The acceptance criterion: k > 1 reaches the same fixpoint with
    STRICTLY fewer halo exchanges than the bulk-synchronous engine (which
    exchanges once per sweep)."""
    p, x0, ref = _batched_fixture(semiring)
    _, ds_sync = PL.distributed_sync_run_batched(
        p, x0, "relax", max_sweeps=100_000)
    assert ds_sync.halo_exchanges == ds_sync.sweeps  # BSP: 1 per sweep
    assert ds_sync.sweeps >= 3, "fixture too shallow to show reduction"
    exchanges = {}
    for k in (1, 2, 4):
        x, ds = AD.distributed_async_run_batched(
            p, x0, max_sweeps=100_000, local_sweeps=k)
        assert np.array_equal(np.asarray(x), ref)
        assert ds.converged
        if k > 1:
            assert ds.halo_exchanges < ds_sync.halo_exchanges
        exchanges[k] = ds.halo_exchanges
    # more local sweeps never needs more exchanges
    assert exchanges[4] <= exchanges[2] <= exchanges[1]


def test_single_source_wrapper_parity():
    """Exchange reduction needs intra-shard propagation to dominate, so
    pin a modest "graph" extent — at d_g=8 on this 200-vertex graph the
    cross-shard hop count (which no k can beat) is the whole fixpoint."""
    g = G.rmat(200, 900, seed=6)
    p = eng.prepare(g, "min_plus", b=8, num_clusters=8)
    x0 = np.full(g.n, np.inf, dtype=np.float32)
    x0[3] = 0.0
    xb = np.asarray(p.to_blocks(x0, np.inf))
    ndev = 2 if len(jax.devices()) >= 2 else 1
    mesh = PL.make_graph_mesh(ndev)
    xs, ds_sync = PL.distributed_sync_run(p, xb, "relax",
                                          max_sweeps=100_000, mesh=mesh)
    xa, ds = AD.distributed_async_run(p, xb, max_sweeps=100_000,
                                      mesh=mesh, local_sweeps=4)
    assert np.array_equal(np.asarray(xa), np.asarray(xs))
    assert ds.converged
    assert ds.halo_exchanges < ds_sync.halo_exchanges


# -- engine guards ------------------------------------------------------


def test_async_engine_rejects_non_relax():
    """PageRank's damped affine update is not idempotent — the k-local-
    sweep schedule would change its fixpoint, so the engine refuses."""
    p, x0, _ = _batched_fixture("min_plus")
    with pytest.raises(ValueError, match="relax"):
        AD.distributed_async_run_batched(p, x0, apply_kind="pagerank")


def test_async_engine_rejects_bad_k():
    p, x0, _ = _batched_fixture("min_plus")
    with pytest.raises(ValueError, match="local_sweeps"):
        AD.distributed_async_run_batched(p, x0, local_sweeps=0)


# -- policy plumbing (API level) ----------------------------------------


def test_policy_routes_async_flavor():
    """End-to-end through GraphProcessor: async flavor is bit-identical
    to the sync flavor and DistStats lands in Result.extra."""
    g = G.rmat(150, 600, seed=3)
    proc = api.GraphProcessor(g, b=8, num_clusters=8)
    pol_s = api.ExecutionPolicy(mode="distributed")
    pol_a = pol_s.but(dist_flavor="async", local_sweeps=4)
    for sources in (0, [0, 3, 7]):
        rs = proc.sssp(sources, policy=pol_s)
        ra = proc.sssp(sources, policy=pol_a)
        assert np.array_equal(rs.values, ra.values)
        ds = ra.extra["dist"]
        assert ds.local_sweeps == 4
        assert ds.halo_exchanges <= rs.extra["dist"].halo_exchanges
        # halo accounting follows exchanges, not sweeps, for the async
        # flavor (engine.dist_run_stats)
        if ds.halo_exchanges < rs.extra["dist"].halo_exchanges:
            assert ra.stats.halo_tiles < rs.stats.halo_tiles


def test_policy_async_pagerank_raises():
    g = G.rmat(150, 600, seed=3)
    proc = api.GraphProcessor(g, b=8, num_clusters=8)
    pol = api.ExecutionPolicy(mode="distributed", dist_flavor="async",
                              local_sweeps=2)
    with pytest.raises(ValueError, match="relax"):
        proc.pagerank(policy=pol)


def test_service_wave_uses_async_engine():
    """Coalesced GraphService waves dispatch through the async engine
    when the policy asks for it, bit-identical to sequential runs."""
    g = G.rmat(150, 600, seed=3)
    pol = api.ExecutionPolicy(mode="distributed", dist_flavor="async",
                              local_sweeps=4, max_sweeps=100_000)
    svc = api.GraphService()
    svc.register("g", g, b=8, num_clusters=8)
    sources = (0, 3, 7)
    tickets = [svc.submit("g", api.QuerySpec(algo="sssp", sources=(s,),
                                             policy=pol))
               for s in sources]
    out = svc.gather()
    proc = api.GraphProcessor(g, b=8, num_clusters=8)
    for t, s in zip(tickets, sources):
        res = out[t]
        assert not isinstance(res, Exception), res
        assert res.extra["coalesced"] == len(sources)
        assert res.extra["dist_flavor"] == "async"
        assert res.extra["dist"].local_sweeps == 4
        seq = proc.sssp(s, policy=pol)
        assert np.array_equal(res.values, seq.values)


# -- subprocess sweep for single-device hosts ---------------------------


_SUBPROCESS_8DEV_ASYNC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
from repro.core import async_dist as AD, engine as E, graph as G, \
    placement as PL
g = G.rmat(200, 900, seed=6)
p = E.prepare(g, "min_plus", b=8, num_clusters=8)
sources = [0, 5, 9, 13, 17]
X0 = np.stack([np.asarray(p.to_blocks(
    np.where(np.arange(g.n) == s, 0, np.inf).astype(np.float32),
    np.inf)) for s in sources])
ref, _ = E.run_sync_batched(p, X0, max_sweeps=100_000)
ref = np.asarray(ref)
_, ds_sync = PL.distributed_sync_run_batched(
    p, X0, "relax", max_sweeps=100_000, mesh=PL.make_graph_mesh(8, 1))
for nd, qa in [(1, 1), (4, 2), (8, 1), (8, 8)]:
    for k in (1, 2, 4):
        m = PL.make_graph_mesh(nd, qa)
        x, ds = AD.distributed_async_run_batched(
            p, X0, max_sweeps=100_000, mesh=m, local_sweeps=k)
        assert np.array_equal(np.asarray(x), ref), (nd, qa, k)
        assert ds.converged and ds.mesh_shape == (nd // qa, qa)
        if k == 4:
            assert ds.halo_exchanges < ds_sync.halo_exchanges, (nd, qa)
print("OK8-ASYNC")
"""


def test_async_distributed_8_fake_devices():
    if len(jax.devices()) >= 8:
        pytest.skip("in-process factorization grid already covers this "
                    "on the multi-device lane")
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", _SUBPROCESS_8DEV_ASYNC],
                         capture_output=True, text=True, env=env,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))), timeout=600)
    assert "OK8-ASYNC" in out.stdout, out.stderr[-2000:]
