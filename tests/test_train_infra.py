"""Training infrastructure: optimizers vs references, accumulation
equivalence, checkpoint/restart determinism, failure recovery, local-SGD,
data pipeline."""

import dataclasses
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import ckpt
from repro.configs import get_config
from repro.data.pipeline import SyntheticCorpus, make_iterator
from repro.models import lm
from repro.train import compress
from repro.train.loop import (SimulatedFailure, TrainArgs, train,
                              train_local_sgd, train_with_restarts)
from repro.train.optimizer import (AdamW, Adafactor, clip_by_global_norm,
                                   warmup_cosine)
from repro.train.step import make_train_step


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------


def test_adamw_matches_numpy_reference(rng):
    opt = AdamW(lr=lambda c: 0.1, b1=0.9, b2=0.99, eps=1e-8,
                weight_decay=0.0, clip=1e9)
    p = {"w": jnp.asarray(rng.standard_normal(5).astype(np.float32))}
    st = opt.init(p)
    m = np.zeros(5)
    v = np.zeros(5)
    pw = np.asarray(p["w"]).copy()
    for t in range(1, 4):
        g = rng.standard_normal(5).astype(np.float32)
        p, st, _ = opt.update({"w": jnp.asarray(g)}, st, p)
        m = 0.9 * m + 0.1 * g
        v = 0.99 * v + 0.01 * g * g
        pw -= 0.1 * (m / (1 - 0.9 ** t)) / (np.sqrt(v / (1 - 0.99 ** t))
                                            + 1e-8)
        np.testing.assert_allclose(np.asarray(p["w"]), pw, rtol=1e-5)


@pytest.mark.parametrize("optname", ["adamw", "adafactor"])
def test_optimizer_descends_quadratic(optname, rng):
    target = jnp.asarray(rng.standard_normal((4, 8)).astype(np.float32))
    p = {"w": jnp.zeros((4, 8))}
    opt = AdamW(lr=lambda c: 0.05) if optname == "adamw" else \
        Adafactor(lr=lambda c: 0.5)
    st = opt.init(p)

    def loss(p):
        return jnp.mean((p["w"] - target) ** 2)

    l0 = float(loss(p))
    for _ in range(60):
        g = jax.grad(loss)(p)
        p, st, _ = opt.update(g, st, p)
    assert float(loss(p)) < 0.2 * l0


def test_adafactor_factored_shapes():
    opt = Adafactor(lr=lambda c: 0.1)
    p = {"a": jnp.zeros((6, 4, 8)), "b": jnp.zeros((5,))}
    st = opt.init(p)
    assert st["stats"]["a"]["vr"].shape == (6, 4)
    assert st["stats"]["a"]["vc"].shape == (6, 8)
    assert st["stats"]["b"]["v"].shape == (5,)
    ax = opt.state_axes({"a": "stack embed mlp", "b": "norm"})
    assert ax["stats"]["a"]["vr"] == "stack embed"
    assert ax["stats"]["a"]["vc"] == "stack mlp"


def test_global_norm_clip():
    g = {"a": jnp.full((4,), 3.0)}
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert abs(float(gn) - 6.0) < 1e-5
    assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-5


def test_warmup_cosine_shape():
    lr = warmup_cosine(1.0, 10, 100)
    assert float(lr(0)) == 0.0
    assert abs(float(lr(10)) - 1.0) < 1e-6
    assert float(lr(100)) < float(lr(50)) < float(lr(10))


# ---------------------------------------------------------------------------
# train step
# ---------------------------------------------------------------------------


def test_grad_accumulation_equivalence(rng):
    cfg = dataclasses.replace(get_config("granite-3-2b").reduced(),
                              compute_dtype="float32")
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    opt = AdamW(lr=warmup_cosine(1e-3, 1, 10))
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 32)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 32)),
                              jnp.int32),
        "loss_mask": jnp.ones((8, 32), jnp.float32),
    }
    s1 = make_train_step(cfg, opt, accum_steps=1)
    s4 = make_train_step(cfg, opt, accum_steps=4,
                         grad_accum_dtype=jnp.float32)
    p1, _, m1 = jax.jit(s1)(params, opt.init(params), batch)
    p4, _, m4 = jax.jit(s4)(params, opt.init(params), batch)
    # microbatched loss mean == full-batch loss (uniform mask)
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 5e-3
    d = max(float(jnp.max(jnp.abs(a - b)))
            for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)))
    assert d < 5e-3


# ---------------------------------------------------------------------------
# checkpoint / restart
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip_and_retention(rng):
    with tempfile.TemporaryDirectory() as d:
        p = {"a": jnp.asarray(rng.standard_normal((3, 4)),
                              jnp.float32),
             "nest": {"b": jnp.arange(5)}}
        for step in (1, 2, 3, 4):
            ckpt.save(d, step, p, meta={"x": 1}, keep=2)
        assert ckpt.latest_step(d) == 4
        assert sorted(int(n[5:]) for n in os.listdir(d)) == [3, 4]
        q, _, meta = ckpt.restore(d, p)
        np.testing.assert_allclose(np.asarray(q["a"]), np.asarray(p["a"]))
        assert meta["step"] == 4


def test_checkpoint_shape_mismatch_raises(rng):
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 1, {"a": jnp.zeros((3,))})
        with pytest.raises(ValueError):
            ckpt.restore(d, {"a": jnp.zeros((4,))})


def test_restart_is_deterministic():
    """train(20) == train(10) + crash + restore + train(10..20)."""
    cfg = get_config("granite-3-2b").reduced()
    base = TrainArgs(steps=14, batch_size=4, seq_len=32, lr=1e-3,
                     warmup=2, log_every=14, ckpt_every=7)
    with tempfile.TemporaryDirectory() as d1:
        out_a = train(cfg, dataclasses.replace(base, ckpt_dir=d1))
    with tempfile.TemporaryDirectory() as d2:
        args = dataclasses.replace(base, ckpt_dir=d2, fail_at_step=9)
        with pytest.raises(SimulatedFailure):
            train(cfg, args)
        out_b = train(cfg, dataclasses.replace(args, fail_at_step=None))
    for a, b in zip(jax.tree.leaves(out_a["params"]),
                    jax.tree.leaves(out_b["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-5, atol=1e-6)


def test_train_with_restarts_recovers():
    cfg = get_config("granite-3-2b").reduced()
    with tempfile.TemporaryDirectory() as d:
        out = train_with_restarts(
            cfg, TrainArgs(steps=12, batch_size=4, seq_len=32,
                           ckpt_dir=d, ckpt_every=4, fail_at_step=6,
                           log_every=6))
        assert out["restarts"] == 1
        assert out["final_step"] == 12


def test_loss_decreases_end_to_end():
    cfg = get_config("granite-3-2b").reduced()
    out = train(cfg, TrainArgs(steps=40, batch_size=8, seq_len=64,
                               lr=2e-3, warmup=5, log_every=10))
    h = out["history"]
    assert h[-1]["loss"] < h[0]["loss"] - 0.3


def test_local_sgd_trains_and_compresses():
    cfg = get_config("granite-3-2b").reduced()
    out = train_local_sgd(
        cfg, TrainArgs(steps=10, batch_size=4, seq_len=32, lr=2e-3,
                       warmup=2), workers=2, sync_period=5)
    assert out["history"][-1]["loss"] < out["history"][0]["loss"] + 0.5
    # int8 deltas: 1 byte/param/transmission (4× less than f32);
    # 2 workers × 2 sync rounds = 4 transmissions
    n_params = sum(x.size for x in jax.tree.leaves(out["params"]))
    transmissions = 2 * 2
    assert out["comm_bytes"] < 1.05 * n_params * transmissions + 1e4
    assert out["comm_bytes"] > 0.9 * n_params * transmissions


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def test_data_determinism_and_shapes():
    c = SyntheticCorpus(vocab_size=512, seed=3)
    b1 = c.batch(7, 4, 64)
    b2 = c.batch(7, 4, 64)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (4, 64)
    # labels are next-token shifted
    np.testing.assert_array_equal(
        c.batch(0, 2, 32)["labels"][:, :-1],
        c.batch(0, 2, 32)["tokens"][:, 1:])


def test_data_shards_differ():
    c = SyntheticCorpus(vocab_size=512, seed=3)
    a = c.batch(0, 2, 64, shard=0, num_shards=4)["tokens"]
    b = c.batch(0, 2, 64, shard=1, num_shards=4)["tokens"]
    assert not np.array_equal(a, b)


def test_iterator_resume():
    c = SyntheticCorpus(vocab_size=128, seed=5)
    it = make_iterator(c, 2, 16)
    seq = [next(it)["tokens"] for _ in range(5)]
    it2 = make_iterator(c, 2, 16, start_step=3)
    np.testing.assert_array_equal(next(it2)["tokens"], seq[3])


def test_compress_roundtrip_tree(rng):
    t = {"a": jnp.asarray(rng.standard_normal((8, 8)), jnp.float32),
         "b": jnp.asarray(rng.standard_normal(16), jnp.float32)}
    q, s, err = compress.compress_tree(t, compress.zeros_error(t))
    deq = compress.decompress_tree(q, s)
    for k in t:
        rel = float(jnp.max(jnp.abs(deq[k] - t[k]))) / \
            float(jnp.max(jnp.abs(t[k])))
        assert rel < 0.02
