"""Fault injection + self-healing serving: the recovery machinery is
tested against the exact failures it claims to absorb.

Every fault here is DETERMINISTIC (``FaultPlan`` seed, default 1234,
override with ``REPRO_FAULT_SEED``) — CI replays the identical fault
sequence.  The load-bearing invariant throughout: with faults injected
at every site, every submitted request still resolves — bit-identical
to the fault-free run after degradation/retry, or with a structured
error — and ``stats()`` reports what the machinery absorbed."""

import os
import threading
import time

import numpy as np
import pytest

from repro import api
from repro import resilience as rz
from repro.core import engine as eng
from repro.core import graph as G
from repro.serve.graph import QUARANTINE_DIR, TUNINGS_LOG

SEED = int(os.environ.get("REPRO_FAULT_SEED", "1234"))


@pytest.fixture(scope="module")
def road():
    return G.road_network(10, seed=1)


@pytest.fixture(autouse=True)
def _no_leaked_plan():
    yield
    # a test that fails mid-``inject`` must not poison its neighbors
    rz.uninstall()


def sssp(s):
    return api.QuerySpec(algo="sssp", sources=(s,))


def fplan(*specs, seed=SEED):
    return rz.FaultPlan(specs, seed=seed)


# ---------------------------------------------------------------------------
# the harness itself
# ---------------------------------------------------------------------------


def test_disabled_injection_is_a_noop():
    assert rz.active() is None
    rz.fire("sched.dispatch", size=3)       # no plan: must not raise
    data = b"payload-bytes"
    assert rz.corrupt_bytes("planstore.disk_read", data) is data


def test_fault_spec_validation():
    with pytest.raises(ValueError, match="unknown fault site"):
        rz.FaultSpec("not.a.site")
    with pytest.raises(ValueError, match="mode"):
        rz.FaultSpec("engine.run", mode="explode")
    with pytest.raises(ValueError, match="p must be"):
        rz.FaultSpec("engine.run", p=1.5)
    with pytest.raises(ValueError, match="exc"):
        rz.FaultSpec("engine.run", exc="valueerror")


def test_plan_is_deterministic_per_seed():
    def pattern(seed):
        plan = fplan(rz.FaultSpec("engine.run", p=0.5), seed=seed)
        fired = []
        with rz.inject(plan):
            for _ in range(64):
                try:
                    rz.fire("engine.run")
                    fired.append(0)
                except rz.FaultInjected:
                    fired.append(1)
        return fired

    assert pattern(SEED) == pattern(SEED)
    assert pattern(SEED) != pattern(SEED + 1)   # and the seed matters
    assert sum(pattern(SEED)) > 0


def test_count_after_and_where_filters():
    plan = fplan(rz.FaultSpec("kernel.select", count=1, after=1,
                              where={"impl": "pallas"}))
    with rz.inject(plan):
        rz.fire("kernel.select", impl="ref")       # filtered by where
        rz.fire("kernel.select", impl="pallas")    # skipped by after
        with pytest.raises(rz.FaultInjected):
            rz.fire("kernel.select", impl="pallas")
        rz.fire("kernel.select", impl="pallas")    # count exhausted
    st = plan.stats()["kernel.select"]
    assert st == {"hits": 4, "injected": 1}


def test_transient_taxonomy():
    assert rz.is_transient(rz.FaultInjected("x"))
    assert rz.is_transient(api.WaveTimeout("x"))
    assert not rz.is_transient(RuntimeError("x"))
    assert not rz.is_transient(ValueError("x"))


def test_install_is_exclusive():
    plan = fplan(rz.FaultSpec("engine.run"))
    with rz.inject(plan):
        with pytest.raises(RuntimeError, match="already installed"):
            rz.install(fplan(rz.FaultSpec("engine.run")))
    assert rz.active() is None


# ---------------------------------------------------------------------------
# plan payload integrity (checksummed framing)
# ---------------------------------------------------------------------------


def test_serialized_plan_roundtrip_and_checksum(road):
    p = eng.prepare(road, "min_plus", b=16)
    blob = api.serialize_prepared(p)
    q = api.deserialize_prepared(blob)
    np.testing.assert_array_equal(np.asarray(p.cols),
                                  np.asarray(q.cols))
    # one flipped byte in the payload is caught by the digest
    pos = len(blob) // 2
    bad = blob[:pos] + bytes([blob[pos] ^ 0xFF]) + blob[pos + 1:]
    with pytest.raises(eng.PlanIntegrityError, match="checksum"):
        api.deserialize_prepared(bad)


def test_legacy_unframed_payloads_still_load(road):
    p = eng.prepare(road, "min_plus", b=16)
    framed = api.serialize_prepared(p)
    legacy = framed[len(eng._PLAN_MAGIC) + eng._PLAN_DIGEST_SIZE:]
    q = api.deserialize_prepared(legacy)    # pre-checksum disk tiers
    np.testing.assert_array_equal(np.asarray(p.vals),
                                  np.asarray(q.vals))


def test_corrupt_disk_plan_quarantined_and_rebuilt(road, tmp_path):
    d = str(tmp_path)
    svc = api.GraphService(cache_dir=d)
    svc.register("g", road, b=16)
    base = svc.run("g", sssp(0))

    svc2 = api.GraphService(cache_dir=d)    # cold restart, corrupt read
    svc2.register("g", road, b=16)
    plan = fplan(rz.FaultSpec("planstore.disk_read", mode="corrupt"))
    with rz.inject(plan):
        r = svc2.run("g", sssp(0))
    assert plan.stats()["planstore.disk_read"]["injected"] >= 1
    np.testing.assert_array_equal(np.asarray(r.values),
                                  np.asarray(base.values))
    st = svc2.stats()["plan_store"]
    assert st["quarantined"] >= 1
    qdir = os.path.join(d, QUARANTINE_DIR)
    assert os.path.isdir(qdir) and len(os.listdir(qdir)) >= 1


def test_disk_write_failure_stays_best_effort(road, tmp_path):
    svc = api.GraphService(cache_dir=str(tmp_path))
    svc.register("g", road, b=16)
    plan = fplan(rz.FaultSpec("planstore.disk_write", exc="oserror"))
    with rz.inject(plan):
        r = svc.run("g", sssp(0))           # query succeeds anyway
    assert r.values.shape == (road.n,)
    assert svc.stats()["plan_store"]["disk_errors"] >= 1


def test_corrupt_sidecar_logs_warn_quarantine_start_fresh(road, tmp_path):
    d = str(tmp_path)
    (tmp_path / TUNINGS_LOG).write_text('{"version": 2, "tunings": [[')
    (tmp_path / "plan_access.json").write_text("garbage{{{")
    with pytest.warns(RuntimeWarning, match="quarantined corrupt"):
        svc = api.GraphService(cache_dir=d)     # must NOT raise
    svc.register("g", road, b=16)
    assert svc.run("g", sssp(0)).values.shape == (road.n,)
    assert svc.stats()["plan_store"]["quarantined"] == 2
    assert len(os.listdir(os.path.join(d, QUARANTINE_DIR))) == 2


def test_tampered_checksum_detected(road, tmp_path):
    import json
    d = str(tmp_path)
    svc = api.GraphService(cache_dir=d)
    svc.register("g", road, b=16)
    svc.run("g", sssp(0))
    svc.store._flush_tunings()
    path = tmp_path / TUNINGS_LOG
    doc = json.loads(path.read_text())
    assert doc["version"] == 2 and "checksum" in doc
    doc["checksum"] = "0" * 32                  # silent bit-rot stand-in
    path.write_text(json.dumps(doc))
    with pytest.warns(RuntimeWarning, match="checksum mismatch"):
        api.GraphService(cache_dir=d)


# ---------------------------------------------------------------------------
# degradation ladder
# ---------------------------------------------------------------------------


def test_degrade_policy_ladder_shape():
    pallas = api.ExecutionPolicy(kernel=api.KernelSpec(impl="pallas"))
    rung1 = api.degrade_policy(pallas)
    assert rung1.kernel.impl == "ref"
    dist = api.ExecutionPolicy(mode="distributed", dist_flavor="async")
    rung2 = api.degrade_policy(dist)
    assert rung2.mode == "sync"
    floor = api.ExecutionPolicy()               # sync + ref: no net
    assert api.degrade_policy(floor) is None


def test_kernel_fault_degrades_to_ref_bit_identical(road):
    proc = api.GraphProcessor(road, b=16)
    base = proc.run(sssp(0))
    pallas = api.ExecutionPolicy(kernel=api.KernelSpec(impl="pallas"))
    plan = fplan(rz.FaultSpec("kernel.select",
                              where={"impl": "pallas"}))
    with rz.inject(plan):
        r = proc.run(api.QuerySpec(algo="sssp", sources=(0,),
                                   policy=pallas))
    np.testing.assert_array_equal(np.asarray(r.values),
                                  np.asarray(base.values))
    steps = r.extra["degraded"]
    assert len(steps) == 1 and "FaultInjected" in steps[0]["error"]
    assert "/pallas" in steps[0]["from"] and "/ref" in steps[0]["to"]


def test_distributed_fault_falls_back_to_single_device_sync(road):
    proc = api.GraphProcessor(road, b=16)
    base = proc.run(sssp(0))
    dist = api.ExecutionPolicy(mode="distributed")
    plan = fplan(rz.FaultSpec("dist.dispatch"))
    with rz.inject(plan):
        r = proc.run(api.QuerySpec(algo="sssp", sources=(0,),
                                   policy=dist))
    np.testing.assert_array_equal(np.asarray(r.values),
                                  np.asarray(base.values))
    assert [s["from"].split("/")[0] for s in r.extra["degraded"]] \
        == ["distributed"]


def test_degrade_false_propagates_the_fault(road):
    proc = api.GraphProcessor(road, b=16)
    hard = api.ExecutionPolicy(kernel=api.KernelSpec(impl="pallas"),
                               degrade=False)
    with rz.inject(fplan(rz.FaultSpec("kernel.select"))):
        with pytest.raises(rz.FaultInjected):
            proc.run(api.QuerySpec(algo="sssp", sources=(0,),
                                   policy=hard))


def test_misuse_errors_never_degrade(road):
    # a bad request fails identically on every rung — degrading would
    # just mask the caller's bug behind N slower failures
    proc = api.GraphProcessor(road, b=16)
    with pytest.raises(IndexError):
        proc.run(api.QuerySpec(algo="sssp", sources=(road.n + 7,)))
    with pytest.raises(ValueError):
        proc.run(api.QuerySpec(algo="nope", sources=(0,)))


def test_service_counts_degraded_runs(road):
    svc = api.GraphService()
    svc.register("g", road, b=16)
    pallas = api.ExecutionPolicy(kernel=api.KernelSpec(impl="pallas"))
    with rz.inject(fplan(rz.FaultSpec("kernel.select", count=1,
                                      where={"impl": "pallas"}))):
        svc.run("g", api.QuerySpec(algo="sssp", sources=(0,),
                                   policy=pallas))
    assert svc.stats()["degraded_runs"] == 1


# ---------------------------------------------------------------------------
# scheduler self-healing: retries, watchdog, structured shutdown
# ---------------------------------------------------------------------------


def server(road, **wave_kw):
    wave = api.WavePolicy(**{"max_wait_s": 0.002,
                             "backoff_base_s": 0.01, **wave_kw})
    srv = api.GraphServer(wave=wave)
    srv.register("g", road, b=16, warm=False)
    return srv


def test_transient_wave_failure_retried_to_success(road):
    with server(road) as srv:
        base = srv.run("g", sssp(0))
        plan = fplan(rz.FaultSpec("sched.dispatch", count=1))
        with rz.inject(plan):
            r = srv.run("g", sssp(0))
        np.testing.assert_array_equal(np.asarray(r.values),
                                      np.asarray(base.values))
        st = srv.stats()["scheduler"]
        assert st["retries"] == 1 and st["failed"] == 0
        assert st["retry_exhausted"] == 0


def test_retry_budget_exhaustion_is_a_structured_failure(road):
    with server(road) as srv:
        with rz.inject(fplan(rz.FaultSpec("sched.dispatch"))):
            fut = srv.submit("g", sssp(0))
            with pytest.raises(rz.FaultInjected):
                fut.result(timeout=60)
        st = srv.stats()["scheduler"]
        assert st["retry_exhausted"] == 1 and st["failed"] == 1
        # initial attempt + max_retries re-dispatches
        assert st["retries"] == api.WavePolicy().max_retries


def test_deterministic_failures_are_never_retried(road):
    with server(road) as srv:
        real = srv.service.run
        calls = []

        def boom(name, spec):
            calls.append(name)
            raise RuntimeError("deterministic bug")

        srv.service.run = boom
        try:
            fut = srv.submit("g", api.QuerySpec(algo="pagerank"))
            with pytest.raises(RuntimeError, match="deterministic"):
                fut.result(timeout=60)
        finally:
            srv.service.run = real
        assert len(calls) == 1
        assert srv.stats()["scheduler"]["retries"] == 0


def test_watchdog_reaps_hung_wave_and_retry_succeeds(road):
    with server(road, watchdog_s=0.3) as srv:
        base = srv.run("g", sssp(0))
        plan = fplan(rz.FaultSpec("sched.dispatch", mode="delay",
                                  delay_s=10.0, count=1))
        with rz.inject(plan):
            r = srv.run("g", sssp(0))
        np.testing.assert_array_equal(np.asarray(r.values),
                                      np.asarray(base.values))
        st = srv.stats()["scheduler"]
        assert st["watchdog_timeouts"] == 1 and st["retries"] == 1


def test_watchdog_timeout_exhausts_to_wave_timeout(road):
    with server(road, watchdog_s=0.2, max_retries=0) as srv:
        plan = fplan(rz.FaultSpec("sched.dispatch", mode="delay",
                                  delay_s=10.0, count=1))
        with rz.inject(plan):
            fut = srv.submit("g", sssp(0))
            with pytest.raises(api.WaveTimeout):
                fut.result(timeout=60)
        assert srv.stats()["scheduler"]["watchdog_timeouts"] == 1


def test_stop_without_drain_resolves_queue_with_server_closed(road):
    srv = api.GraphServer(autostart=False)   # paused: queue accumulates
    srv.register("g", road, b=16, warm=False)
    futs = [srv.submit("g", sssp(s)) for s in (0, 1, 2)]
    srv.close(drain=False)
    for f in futs:
        with pytest.raises(api.ServerClosed) as ei:
            f.result(timeout=10)
        assert isinstance(ei.value, api.Backpressure)   # structured
        assert isinstance(ei.value.stats, dict)
    with pytest.raises(api.ServerClosed, match="closed"):
        srv.submit("g", sssp(0))


def test_offer_after_stop_resolves_immediately(road):
    from concurrent.futures import Future

    from repro.serve.sched import _Request
    srv = api.GraphServer(autostart=False)
    srv.register("g", road, b=16, warm=False)
    srv.close(drain=False)
    fut = Future()
    srv.sched.offer(_Request(ticket=0, name="g", spec=sssp(0), key=None,
                             future=fut, t_submit=time.monotonic(),
                             t_deadline=None))
    with pytest.raises(api.ServerClosed):
        fut.result(timeout=10)


# ---------------------------------------------------------------------------
# stress: concurrent register / evict / submit (satellite)
# ---------------------------------------------------------------------------


def test_concurrent_register_evict_submit_no_orphans(road):
    """Hammer one server from register/evict/submit threads: no
    deadlock, and EVERY submitted future resolves (a Result or a
    structured KeyError/Backpressure) — no orphans."""
    small = G.road_network(6, seed=2)
    with server(road, max_wait_s=0.001) as srv:
        stop_evt = threading.Event()
        futs, errs = [], []
        lock = threading.Lock()

        def churn():     # register/evict a second graph in a loop
            while not stop_evt.is_set():
                try:
                    srv.register("churn", small, b=8, warm=False)
                    time.sleep(0.002)
                    srv.evict("churn")
                except Exception as e:  # pragma: no cover
                    errs.append(e)

        def submitter(i):
            for k in range(20):
                name = "churn" if (i + k) % 3 == 0 else "g"
                try:
                    f = srv.submit(name, sssp(k % road.n
                                              if name == "g" else 0))
                except (KeyError, api.Backpressure):
                    continue     # evicted that instant / queue full
                with lock:
                    futs.append(f)

        threads = [threading.Thread(target=churn)] + \
            [threading.Thread(target=submitter, args=(i,))
             for i in range(4)]
        for t in threads:
            t.start()
        for t in threads[1:]:
            t.join(timeout=120)
        stop_evt.set()
        threads[0].join(timeout=30)
        assert not any(t.is_alive() for t in threads)
        assert not errs
        base = np.asarray(srv.run("g", sssp(0)).values)
        for f in futs:
            try:
                r = f.result(timeout=60)    # every future resolves
            except (KeyError, api.Backpressure, api.DeadlineExceeded):
                continue                    # structured, acceptable
            if r.extra.get("src") == 0 and r.graph is road:
                np.testing.assert_array_equal(np.asarray(r.values),
                                              base)


# ---------------------------------------------------------------------------
# the acceptance story: faults at every site, every request resolves
# ---------------------------------------------------------------------------


def test_multi_site_faults_every_request_resolves(road, tmp_path):
    srv = api.GraphServer(cache_dir=str(tmp_path),
                          wave=api.WavePolicy(max_wait_s=0.002,
                                              backoff_base_s=0.01,
                                              watchdog_s=2.0))
    srv.register("g", road, b=16, warm=False)
    base = {s: np.asarray(srv.run("g", sssp(s)).values)
            for s in range(4)}
    plan = fplan(
        rz.FaultSpec("planstore.disk_read", mode="corrupt", p=0.5),
        rz.FaultSpec("planstore.disk_write", exc="oserror", p=0.5),
        rz.FaultSpec("kernel.select", count=1,
                     where={"impl": "pallas"}),
        rz.FaultSpec("sched.dispatch", p=0.3, count=3),
        rz.FaultSpec("sched.dispatch", mode="delay", delay_s=5.0,
                     count=1, after=1),
    )
    pallas = api.ExecutionPolicy(kernel=api.KernelSpec(impl="pallas"))
    with rz.inject(plan):
        futs = {}
        for rep in range(3):
            for s in range(4):
                spec = api.QuerySpec(algo="sssp", sources=(s,),
                                     policy=pallas if s == 0 else None)
                futs[(rep, s)] = srv.submit("g", spec)
        outcomes = {"ok": 0, "err": 0}
        for (rep, s), f in futs.items():
            try:
                r = f.result(timeout=120)   # EVERY future resolves
            except (rz.FaultInjected, api.WaveTimeout, OSError,
                    api.Backpressure):
                outcomes["err"] += 1        # structured, transient
                continue
            outcomes["ok"] += 1             # …or bit-identical
            np.testing.assert_array_equal(np.asarray(r.values),
                                          base[s])
    srv.close()
    fired = plan.stats()
    assert fired.get("sched.dispatch", {}).get("injected", 0) >= 1
    assert outcomes["ok"] >= 1
    sched = srv.stats()["scheduler"]
    assert sched["completed"] + sched["failed"] >= len(futs)
    assert sched["retries"] >= 1
