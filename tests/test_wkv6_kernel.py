"""wkv6 Pallas kernel sweep vs scan oracle vs the model's _wkv_scan."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.wkv6 import wkv6, wkv6_ref
from repro.models import rwkv


@pytest.mark.parametrize("bh,t,hs,chunk", [(4, 128, 16, 32),
                                           (2, 64, 32, 64),
                                           (3, 96, 8, 16),
                                           (1, 200, 16, 50)])
def test_wkv6_matches_oracle(bh, t, hs, chunk, rng):
    r = jnp.asarray(rng.standard_normal((bh, t, hs)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((bh, t, hs)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((bh, t, hs)), jnp.float32)
    w = jnp.asarray(rng.random((bh, t, hs)) * 0.5 + 0.4, jnp.float32)
    u = jnp.asarray(rng.standard_normal(hs), jnp.float32)
    s0 = jnp.asarray(rng.standard_normal((bh, hs, hs)) * 0.1, jnp.float32)
    y1, s1 = wkv6(r, k, v, w, u, s0, chunk=chunk)
    y2, s2 = wkv6_ref(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-4)


def test_wkv6_matches_model_scan(rng):
    """Kernel == the model's multi-head _wkv_scan (same math, different
    layout): (B,S,H,hs) vs flattened (B·H, S, hs)."""
    b, s, h, hs = 2, 64, 3, 16
    mk = lambda: jnp.asarray(rng.standard_normal((b, s, h, hs)),  # noqa
                             jnp.float32)
    r, k, v = mk(), mk(), mk()
    w = jnp.asarray(rng.random((b, s, h, hs)) * 0.5 + 0.4, jnp.float32)
    u = jnp.asarray(rng.standard_normal((h, hs)), jnp.float32)
    st0 = jnp.zeros((b, h, hs, hs), jnp.float32)
    y_model, s_model = rwkv._wkv_scan(r, k, v, w, u, st0)
    # flatten heads; per-head u differs → run kernel per head
    for hh in range(h):
        fl = lambda x: x[:, :, hh, :]  # noqa: E731
        y_k, s_k = wkv6(fl(r), fl(k), fl(v), fl(w), u[hh],
                        st0[:, hh], chunk=32)
        np.testing.assert_allclose(np.asarray(y_k),
                                   np.asarray(y_model[:, :, hh, :]),
                                   atol=2e-4)
        np.testing.assert_allclose(np.asarray(s_k),
                                   np.asarray(s_model[:, hh]), atol=2e-4)


_ = jax
