"""Session API: plan cache, batched multi-source queries, ExecutionPolicy
dispatch (sync / async / pallas / distributed), uniform Result."""

import numpy as np
import pytest

from repro import api
from repro.core import graph as G
from repro.core import oracles as O


@pytest.fixture(scope="module")
def road():
    return G.road_network(10, seed=1)


@pytest.fixture(scope="module")
def proc(road):
    return api.GraphProcessor(road, b=16, num_clusters=8)


def test_plan_cache_hit_identity_and_values(road, proc):
    r1 = proc.pagerank()
    calls = proc.cache_info()["prepare_calls"]
    r2 = proc.pagerank()
    # second query: zero re-clustering — same Prepared object, no new
    # compile-time work
    assert r2.prepared is r1.prepared
    assert proc.cache_info()["prepare_calls"] == calls
    np.testing.assert_array_equal(r1.values, r2.values)
    pr = O.pagerank_oracle(road, tol=1e-12)
    assert np.max(np.abs(r1.values - pr)) < 1e-5


def test_plan_cache_shared_across_queries_not_algorithms(road, proc):
    d0 = proc.sssp(0)
    d5 = proc.sssp(5)
    assert d0.prepared is d5.prepared          # same plan, new source
    np.testing.assert_allclose(d5.values, O.sssp_oracle(road, 5),
                               rtol=1e-5, atol=1e-4)
    # bfs runs min_plus on the unit-weight variant → distinct plan
    lb = proc.bfs(0)
    assert lb.prepared is not d0.prepared
    keys = proc.cache_info()["keys"]
    assert len(keys) == len(set(keys))


@pytest.mark.parametrize("mode", ["sync", "async"])
def test_batched_multi_source_sssp(road, proc, mode):
    sources = [0, 3, 7, 11]
    pol = api.ExecutionPolicy(mode=mode, max_sweeps=100_000)
    r = proc.sssp(sources=sources, policy=pol)
    assert r.values.shape == (len(sources), road.n)
    for q, s in enumerate(sources):
        np.testing.assert_allclose(r.values[q], O.sssp_oracle(road, s),
                                   rtol=1e-5, atol=1e-4)
    assert r.stats.converged
    assert r.extra["sources"] == sources


@pytest.mark.parametrize("mode", ["sync", "async"])
def test_batched_multi_source_bfs(road, proc, mode):
    sources = [0, 2, 9]
    pol = api.ExecutionPolicy(mode=mode, max_sweeps=100_000)
    r = proc.bfs(sources=sources, policy=pol)
    for q, s in enumerate(sources):
        np.testing.assert_array_equal(r.values[q], O.bfs_oracle(road, s))


def test_batched_shares_plan_with_single_source(road, proc):
    single = proc.sssp(0)
    batched = proc.sssp(sources=[0, 1])
    assert batched.prepared is single.prepared
    np.testing.assert_allclose(batched.values[0], single.values,
                               rtol=1e-6, atol=1e-6)


def test_async_pallas_policy_matches_oracles(road, proc):
    """Satellite check: impl plumbs through the async engine's bsr_spmv
    (the seed hardcoded "ref" there, making Pallas unreachable)."""
    pol = api.ExecutionPolicy(mode="async", impl="pallas",
                              max_sweeps=100_000)
    d = proc.sssp(0, policy=pol)
    np.testing.assert_allclose(d.values, O.sssp_oracle(road, 0),
                               rtol=1e-5, atol=1e-4)
    pr = proc.pagerank(policy=pol.but(tol=1e-8, max_sweeps=500))
    assert np.max(np.abs(pr.values
                         - O.pagerank_oracle(road, tol=1e-12))) < 1e-5


def test_distributed_policy(road, proc):
    pol = api.ExecutionPolicy(mode="distributed")
    d = proc.sssp(0, policy=pol)
    np.testing.assert_allclose(d.values, O.sssp_oracle(road, 0),
                               rtol=1e-5, atol=1e-4)
    assert d.stats.mode == "distributed"
    assert d.extra["dist"].converged


def test_all_six_algorithms_through_processor(road, proc):
    assert proc.sssp(0).stats.converged
    assert np.array_equal(proc.bfs(0).values, O.bfs_oracle(road, 0))
    assert abs(proc.pagerank().values.sum() - 1.0) < 1e-5
    cc = proc.connected_components()
    labels = {}
    for i, l_ in enumerate(cc.values):
        labels.setdefault(round(float(l_), 4), set()).add(i)
    oracle_labels = {}
    for i, l_ in enumerate(O.cc_oracle(road)):
        oracle_labels.setdefault(int(l_), set()).add(i)
    assert sorted(map(frozenset, labels.values())) == \
        sorted(map(frozenset, oracle_labels.values()))
    tri = proc.minitri()
    assert tri.extra["triangles"] == O.triangles_oracle(road)
    d = proc.dfs(0)
    order, parent = O.dfs_oracle(road, 0)
    nv = d.extra["visited_count"]
    assert nv == len(order)
    np.testing.assert_array_equal(d.values[:nv], order)


def test_reachability_through_processor(road, proc):
    r = proc.reachability(0)
    np.testing.assert_array_equal(r.values > 0,
                                  np.isfinite(O.bfs_oracle(road, 0)))


def test_policy_validation():
    with pytest.raises(ValueError):
        api.ExecutionPolicy(mode="turbo")
    with pytest.raises(ValueError):
        api.ExecutionPolicy(impl="cuda")
    pol = api.ExecutionPolicy()
    assert pol.but(mode="sync").mode == "sync"
    assert pol.mode == "async"  # frozen: but() copies


def test_policy_dist_flavor_validation():
    """Incoherent dist_flavor / local_sweeps combos fail loudly at
    construction, not deep in dispatch."""
    with pytest.raises(ValueError, match="local_sweeps"):
        api.ExecutionPolicy(local_sweeps=0)
    with pytest.raises(ValueError, match="dist_flavor"):
        api.ExecutionPolicy(dist_flavor="turbo")
    with pytest.raises(ValueError, match="mode='distributed'"):
        api.ExecutionPolicy(dist_flavor="async")  # default mode=async
    with pytest.raises(ValueError, match="dist_flavor='async'"):
        api.ExecutionPolicy(mode="sync", local_sweeps=2)
    with pytest.raises(ValueError, match="per-source"):
        api.ExecutionPolicy(mode="distributed", dist_flavor="async",
                            query_axis=0)
    pol = api.ExecutionPolicy(mode="distributed", dist_flavor="async",
                              local_sweeps=4)
    assert pol.local_sweeps == 4
    # but() re-validates: dropping the mode invalidates the flavor
    with pytest.raises(ValueError, match="mode='distributed'"):
        pol.but(mode="sync")


def test_result_platform_models(road, proc):
    r_async = proc.sssp(0)
    models = r_async.platform_models()
    assert set(models) == {"nale", "cpu"}  # gpu needs sync sweep counts
    r_sync = proc.sssp(0, policy=api.ExecutionPolicy(mode="sync",
                                                     max_sweeps=100_000))
    models = r_async.platform_models(sync_stats=r_sync.stats)
    assert models["nale"].cycles > 0
    assert models["gpu"].cycles > 0
    with pytest.raises(ValueError):
        proc.minitri().platform_models()


def test_run_spec_entry_point(road, proc):
    r = proc.run(api.QuerySpec(algo="sssp", sources=(0,)))
    np.testing.assert_allclose(r.values, O.sssp_oracle(road, 0),
                               rtol=1e-5, atol=1e-4)


def test_run_spec_requires_sources(proc):
    for algo in ("sssp", "bfs", "reachability", "dfs"):
        with pytest.raises(ValueError, match="source"):
            proc.run(api.QuerySpec(algo=algo))
    with pytest.raises(ValueError, match="source"):
        proc.sssp(sources=[])


def test_run_spec_params_override_policy(proc):
    r = proc.run(api.QuerySpec(algo="sssp", sources=(0,),
                               params=(("max_sweeps", 1),)))
    assert r.policy.max_sweeps == 1
    assert r.stats.sweeps <= 1 and not r.stats.converged


def test_run_spec_params_accepts_plain_dict(proc):
    spec = api.QuerySpec(algo="sssp", sources=(0,),
                         params={"max_sweeps": 1, "tol": 1e-3})
    # dicts normalize to the historical tuple form (spec stays hashable)
    assert spec.params == (("max_sweeps", 1), ("tol", 1e-3))
    hash(spec)
    r = proc.run(spec)
    assert r.policy.max_sweeps == 1 and r.policy.tol == 1e-3
    r2 = proc.run(api.QuerySpec(algo="sssp", sources=(0,),
                                params=(("max_sweeps", 1),
                                        ("tol", 1e-3))))
    assert r2.policy == r.policy  # back-compat form still accepted
    # both forms normalize to one sorted tuple: equivalent specs are
    # equal and hash equal regardless of input order
    a = api.QuerySpec(algo="sssp", sources=(0,),
                      params=(("tol", 1e-3), ("max_sweeps", 1)))
    assert a == spec and hash(a) == hash(spec)


def test_batched_distributed_is_single_2d_dispatch(road, proc):
    """Tentpole: batched mode='distributed' runs as ONE 2-D shard_map
    dispatch (no per-source Python loop) — `dist.batched_fallback` must
    NOT appear in Result extras by default — and matches the sync
    batched oracle."""
    sources = [0, 3, 7]
    pol = api.ExecutionPolicy(mode="distributed", max_sweeps=100_000)
    r = proc.sssp(sources=sources, policy=pol)
    assert r.values.shape == (len(sources), road.n)
    assert "batched_fallback" not in r.extra          # fallback retired
    dist = r.extra["dist"]
    assert dist.query_sweeps.shape == (len(sources),)
    assert r.stats.sweeps == int(dist.query_sweeps.max())
    assert r.stats.mode == "distributed" and r.stats.converged
    oracle = proc.sssp(sources=sources,
                       policy=api.ExecutionPolicy(mode="sync",
                                                  max_sweeps=100_000))
    # same engine math, same order of operations: bit-identical
    np.testing.assert_array_equal(r.values, oracle.values)


def test_batched_distributed_query_axis_0_escape_hatch(road, proc):
    """query_axis=0 keeps the retired per-source sequential loop as an
    explicit escape hatch, bit-identical to the 2-D dispatch."""
    sources = [0, 3, 7]
    pol = api.ExecutionPolicy(mode="distributed", max_sweeps=100_000,
                              query_axis=0)
    r = proc.sssp(sources=sources, policy=pol)
    assert r.extra["batched_fallback"] == "per-source sequential"
    batched = proc.sssp(sources=sources,
                        policy=pol.but(query_axis=None))
    np.testing.assert_array_equal(r.values, batched.values)
    assert r.stats.sweeps == batched.stats.sweeps
    with pytest.raises(ValueError, match="query_axis"):
        api.ExecutionPolicy(query_axis=-1)


def test_method_kwargs_merge_into_policy(proc):
    r = proc.pagerank(tol=1e-2, policy=api.ExecutionPolicy(mode="async"))
    assert r.policy.tol == 1e-2 and r.policy.mode == "async"


def test_free_functions_still_work_and_match(road):
    from repro.core import algorithms as A
    r = A.sssp(road, 0, mode="async", b=16, num_clusters=8)
    np.testing.assert_allclose(r.values, O.sssp_oracle(road, 0),
                               rtol=1e-5, atol=1e-4)
    assert r.prepared is not None  # AlgoResult layout preserved
    assert isinstance(r, api.Result)
