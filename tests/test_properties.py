"""Hypothesis property tests on the system's invariants."""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="hypothesis not installed in this container; property tests "
           "are exercised where it is available")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import algorithms as A
from repro.core import cluster as C
from repro.core import graph as G
from repro.core import oracles as O
from repro.core import semiring as sr
from repro.kernels import ops
from repro.train import compress

graphs = st.builds(
    lambda n, d, seed: G.rmat(n, n * d, seed=seed),
    n=st.integers(24, 120), d=st.integers(2, 6), seed=st.integers(0, 99))


@settings(max_examples=12, deadline=None)
@given(graphs, st.integers(2, 8), st.integers(4, 16))
def test_cluster_perm_is_permutation_and_balanced(g, k, b):
    c = C.cluster_graph(g, k)
    assert sorted(c.perm.tolist()) == list(range(g.n))
    assert c.sizes.sum() == g.n
    assert c.balance() <= 2.0  # contiguous-chunk clustering is balanced
    assert sorted(c.schedule.tolist()) == list(range(c.num_clusters))
    _ = b


@settings(max_examples=10, deadline=None)
@given(graphs, st.integers(0, 10))
def test_sssp_async_matches_dijkstra(g, src_seed):
    src = src_seed % g.n
    r = A.sssp(g, src, mode="async", b=8, num_clusters=6)
    np.testing.assert_allclose(r.values, O.sssp_oracle(g, src),
                               rtol=1e-5, atol=1e-4)


@settings(max_examples=8, deadline=None)
@given(graphs)
def test_pagerank_l1_and_engines_agree(g):
    ra = A.pagerank(g, tol=1e-10, mode="async", b=8, num_clusters=6)
    rs = A.pagerank(g, tol=1e-10, mode="sync", b=8, num_clusters=6)
    assert abs(ra.values.sum() - 1.0) < 1e-4
    np.testing.assert_allclose(ra.values, rs.values, rtol=1e-3, atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(graphs)
def test_async_work_never_exceeds_sync(g):
    """The self-timed engine's edge work ≤ bulk-synchronous edge work —
    the paper's core efficiency claim, as an invariant."""
    ra = A.sssp(g, 0, mode="async", b=8, num_clusters=6)
    rs = A.sssp(g, 0, mode="sync", b=8, num_clusters=6)
    assert ra.stats.edge_work <= rs.stats.edge_work + 1e-6
    np.testing.assert_allclose(ra.values, rs.values, rtol=1e-5, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(graphs, st.sampled_from(["plus_times", "min_plus", "max_min"]))
def test_spmv_invariant_under_clustering_permutation(g, semi):
    """SpMV commutes with vertex relabeling — clustering cannot change
    results, only locality."""
    rng = np.random.default_rng(1)
    x = rng.random(g.n).astype(np.float32)
    if semi == "max_min":
        x = (x > 0.5).astype(np.float32)
    z = float(sr.get(semi).zero)

    def spmv(graph, xv):
        bsr = G.to_bsr(graph, b=8, pad_value=z)
        xb = np.full(bsr.n_pad, z, np.float32)
        xb[: graph.n] = xv
        y = ops.bsr_spmv(jnp.asarray(bsr.block_vals),
                         jnp.asarray(bsr.block_cols),
                         jnp.asarray(bsr.block_nnz),
                         jnp.asarray(xb.reshape(bsr.r, bsr.b)),
                         semiring=semi, impl="ref")
        return np.asarray(y).reshape(-1)[: graph.n]

    c = C.cluster_graph(g, 6)
    g2 = g.permute(c.perm.astype(np.int32))
    y1 = spmv(g, x)
    x2 = np.empty_like(x)
    x2[c.perm] = x  # new-id layout
    y2 = spmv(g2, x2)
    # old vertex v lives at new id perm[v]
    np.testing.assert_allclose(y1, y2[c.perm], rtol=1e-4, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(st.lists(st.floats(-1e3, 1e3), min_size=4, max_size=64))
def test_int8_compression_error_bound(xs):
    x = jnp.asarray(np.array(xs, np.float32))
    q, s = compress.quantize(x)
    err = np.abs(np.asarray(compress.dequantize(q, s)) - np.asarray(x))
    assert err.max() <= float(s) * 0.5 + 1e-6


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 40))
def test_error_feedback_mean_converges(seed):
    """EF-quantized repeated transmission of a constant tensor: the
    running mean of decoded values converges to the true value."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(32).astype(np.float32))
    err = jnp.zeros(32, jnp.float32)
    acc = np.zeros(32)
    n = 24
    for _ in range(n):
        q, s, err = compress.compress_tree(x, err)
        acc += np.asarray(compress.dequantize(q, s))
    np.testing.assert_allclose(acc / n, np.asarray(x), atol=2e-2)


@settings(max_examples=30, deadline=None)
@given(st.sampled_from(list(sr.SEMIRINGS)),
       st.lists(st.floats(0.0, 100.0), min_size=3, max_size=3))
def test_semiring_axioms(name, vals):
    s = sr.get(name)
    if s.name == "max_min":
        vals = [min(v / 100.0, 1.0) for v in vals]  # {0..1} carrier
    a, b, c = [jnp.float32(v) for v in vals]
    # ⊕ associative + commutative; zero is ⊕-identity
    np.testing.assert_allclose(s.add(a, s.add(b, c)),
                               s.add(s.add(a, b), c), rtol=1e-6)
    np.testing.assert_allclose(s.add(a, b), s.add(b, a), rtol=1e-6)
    np.testing.assert_allclose(s.add(a, jnp.float32(s.zero)), a, rtol=1e-6)
    # ⊗: one is ⊗-identity (w side) on the semiring's carrier
    if name != "min_select":
        np.testing.assert_allclose(s.mul(jnp.float32(s.one), a), a,
                                   rtol=1e-6)
