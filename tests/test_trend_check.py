"""The CI bench-trend gate: regression math and its failure modes
(missing entries and empty snapshots must not silently pass)."""

from benchmarks import trend_check


def _snap(entries):
    return {"meta": {"scale": 0.002},
            "fig5": [{"graph": g, "algo": a, "speedup_cpu": s}
                     for (g, a), s in entries.items()]}


BASE = {("ca", "sssp"): 50.0, ("ca", "bfs"): 40.0, ("fb", "sssp"): 20.0}


def test_identical_snapshots_pass():
    assert trend_check.compare(_snap(BASE), _snap(BASE), 0.25) == 0


def test_small_drift_within_budget_passes():
    fresh = {k: v * 0.9 for k, v in BASE.items()}   # -10% geomean
    assert trend_check.compare(_snap(BASE), _snap(fresh), 0.25) == 0


def test_large_regression_fails():
    fresh = {k: v * 0.5 for k, v in BASE.items()}   # -50% geomean
    assert trend_check.compare(_snap(BASE), _snap(fresh), 0.25) == 1


def test_missing_baseline_entry_fails():
    fresh = dict(BASE)
    del fresh[("fb", "sssp")]                        # emission broke
    assert trend_check.compare(_snap(BASE), _snap(fresh), 0.25) == 1


def test_speedup_collapse_to_zero_fails():
    fresh = {**BASE, ("ca", "sssp"): 0.0}
    assert trend_check.compare(_snap(BASE), _snap(fresh), 0.25) == 1


def test_empty_baseline_skips_gate():
    assert trend_check.compare(_snap({}), _snap(BASE), 0.25) == 0


def test_new_entries_in_fresh_are_tolerated():
    fresh = {**BASE, ("lj", "cc"): 30.0}             # new algo added
    assert trend_check.compare(_snap(BASE), _snap(fresh), 0.25) == 0


# -- sweep-family handling (fig5 × distributed_batched) -------------------

DIST = [{"graph": "ca", "algo": "sssp", "speedup_vs_sequential": 3.0},
        {"graph": "fb", "algo": "sssp", "speedup_vs_sequential": 2.8}]


def test_family_only_in_fresh_skips_with_warning(capsys):
    fresh = {**_snap(BASE), "distributed_batched": DIST}
    # baseline predates the family: it must not fail the gate
    assert trend_check.compare(_snap(BASE), fresh, 0.25) == 0
    assert "present only in the fresh" in capsys.readouterr().out


def test_family_only_in_baseline_skips_with_warning(capsys):
    base = {**_snap(BASE), "distributed_batched": DIST}
    # a lane that skipped the family must not fail the gate
    assert trend_check.compare(base, _snap(BASE), 0.25) == 0
    assert "present only in the baseline" in capsys.readouterr().out


def test_family_in_both_is_gated():
    base = {**_snap(BASE), "distributed_batched": DIST}
    regressed = [dict(r, speedup_vs_sequential=1.0) for r in DIST]
    fresh = {**_snap(BASE), "distributed_batched": regressed}
    assert trend_check.compare(base, fresh, 0.25) == 1
    assert trend_check.compare(base, base, 0.25) == 0


def test_family_regression_does_not_hide_behind_fig5():
    # fig5 healthy, distributed_batched collapsed: families gate
    # independently — a healthy family must not average away a broken one
    base = {**_snap(BASE), "distributed_batched": DIST}
    fresh = {**_snap({k: v * 2 for k, v in BASE.items()}),
             "distributed_batched": [
                 dict(r, speedup_vs_sequential=0.1) for r in DIST]}
    assert trend_check.compare(base, fresh, 0.25) == 1
