"""The CI bench-trend gate: regression math and its failure modes
(missing entries and empty snapshots must not silently pass)."""

from benchmarks import trend_check


def _snap(entries):
    return {"meta": {"scale": 0.002},
            "fig5": [{"graph": g, "algo": a, "speedup_cpu": s}
                     for (g, a), s in entries.items()]}


BASE = {("ca", "sssp"): 50.0, ("ca", "bfs"): 40.0, ("fb", "sssp"): 20.0}


def test_identical_snapshots_pass():
    assert trend_check.compare(_snap(BASE), _snap(BASE), 0.25) == 0


def test_small_drift_within_budget_passes():
    fresh = {k: v * 0.9 for k, v in BASE.items()}   # -10% geomean
    assert trend_check.compare(_snap(BASE), _snap(fresh), 0.25) == 0


def test_large_regression_fails():
    fresh = {k: v * 0.5 for k, v in BASE.items()}   # -50% geomean
    assert trend_check.compare(_snap(BASE), _snap(fresh), 0.25) == 1


def test_missing_baseline_entry_fails():
    fresh = dict(BASE)
    del fresh[("fb", "sssp")]                        # emission broke
    assert trend_check.compare(_snap(BASE), _snap(fresh), 0.25) == 1


def test_speedup_collapse_to_zero_fails():
    fresh = {**BASE, ("ca", "sssp"): 0.0}
    assert trend_check.compare(_snap(BASE), _snap(fresh), 0.25) == 1


def test_empty_baseline_skips_gate():
    assert trend_check.compare(_snap({}), _snap(BASE), 0.25) == 0


def test_new_entries_in_fresh_are_tolerated():
    fresh = {**BASE, ("lj", "cc"): 30.0}             # new algo added
    assert trend_check.compare(_snap(BASE), _snap(fresh), 0.25) == 0
