"""Serving gateway: Prepared (de)serialization, the bounded LRU plan
store with its persistent disk tier, warm restarts, and the coalescing
submit/gather front door."""

import numpy as np
import pytest

from repro import api
from repro.core import engine as eng
from repro.core import graph as G
from repro.core import oracles as O


@pytest.fixture(scope="module")
def road():
    return G.road_network(10, seed=1)


# ---------------------------------------------------------------------------
# fingerprint + Prepared round-trip
# ---------------------------------------------------------------------------


def test_graph_fingerprint_content_based(road):
    same = G.Graph(n=road.n, indptr=road.indptr.copy(),
                   indices=road.indices.copy(),
                   weights=road.weights.copy())
    assert road.fingerprint() == same.fingerprint()
    other = G.Graph(n=road.n, indptr=road.indptr, indices=road.indices,
                    weights=road.weights + 1.0)
    assert road.fingerprint() != other.fingerprint()


def test_prepared_serialize_roundtrip(road):
    p = api.GraphProcessor(road, b=16, num_clusters=8).prepare("min_plus")
    p2 = api.deserialize_prepared(api.serialize_prepared(p))
    for f in eng._PREPARED_DEVICE_FIELDS:
        np.testing.assert_array_equal(np.asarray(getattr(p2, f)),
                                      np.asarray(getattr(p, f)), err_msg=f)
    for f in ("n", "b", "r_pad", "k_max", "gb", "s", "semiring",
              "tiles_total", "edges_total"):
        assert getattr(p2, f) == getattr(p, f), f
    np.testing.assert_array_equal(p2.perm, p.perm)
    np.testing.assert_array_equal(p2.inv_perm, p.inv_perm)
    np.testing.assert_array_equal(p2.clustering.schedule,
                                  p.clustering.schedule)
    np.testing.assert_array_equal(p2.clustering.assign, p.clustering.assign)
    # the rebuilt plan is executable and agrees with the original
    x0 = p2.to_blocks(np.where(np.arange(road.n) == 0, 0.0,
                               np.inf).astype(np.float32), np.inf)
    x, stats = eng.run_async(p2, x0)
    np.testing.assert_allclose(p2.from_blocks(x), O.sssp_oracle(road, 0),
                               rtol=1e-5, atol=1e-4)
    assert stats.converged


def test_prepared_is_a_pytree(road):
    import jax
    p = api.GraphProcessor(road, b=16, num_clusters=8).prepare("min_plus")
    leaves, treedef = jax.tree_util.tree_flatten(p)
    assert len(leaves) == len(eng._PREPARED_DEVICE_FIELDS)
    p2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert isinstance(p2, eng.Prepared)
    assert p2.semiring == p.semiring and p2.n == p.n
    np.testing.assert_array_equal(np.asarray(p2.vals), np.asarray(p.vals))


def test_deserialize_rejects_future_versions(road):
    import io
    import json
    p = api.GraphProcessor(road, b=16, num_clusters=8).prepare("min_plus")
    # strip the integrity frame to poke the npz payload underneath
    payload = eng._unframe_payload(api.serialize_prepared(p))
    with np.load(io.BytesIO(payload)) as z:
        arrays = {k: z[k] for k in z.files}
    meta = json.loads(arrays["__meta__"].tobytes().decode())
    meta["version"] = 99
    arrays["__meta__"] = np.frombuffer(json.dumps(meta).encode(),
                                       dtype=np.uint8)
    buf = io.BytesIO()
    np.savez(buf, **arrays)
    with pytest.raises(ValueError, match="version"):
        api.deserialize_prepared(buf.getvalue())


# ---------------------------------------------------------------------------
# PlanStore: LRU byte budget + disk tier
# ---------------------------------------------------------------------------


def _plan_key(i: int) -> api.PlanKey:
    return api.PlanKey("min_plus", "base", True, None, 16, 4 + i, True)


def test_plan_store_lru_eviction_order(road):
    proc = api.GraphProcessor(road, b=16, num_clusters=8)
    p = proc.prepare("min_plus")
    store = api.PlanStore(max_bytes=int(p.nbytes * 2.5))  # fits 2 plans
    fp = road.fingerprint()
    store.put(fp, _plan_key(0), p)
    store.put(fp, _plan_key(1), p)
    assert (fp, _plan_key(0)) in store and (fp, _plan_key(1)) in store
    store.get(fp, _plan_key(0))          # touch 0: now 1 is the LRU
    store.put(fp, _plan_key(2), p)       # over budget → evicts 1, not 0
    assert (fp, _plan_key(1)) not in store
    assert (fp, _plan_key(0)) in store and (fp, _plan_key(2)) in store
    st = store.stats()
    assert st["evictions"] == 1 and st["plans"] == 2
    assert st["bytes"] <= store.max_bytes
    assert store.get(fp, _plan_key(1)) is None  # no disk tier: gone


def test_plan_store_disk_tier_backfills_eviction(road, tmp_path):
    proc = api.GraphProcessor(road, b=16, num_clusters=8)
    p = proc.prepare("min_plus")
    store = api.PlanStore(max_bytes=int(p.nbytes * 1.5),  # fits 1 plan
                          cache_dir=str(tmp_path))
    fp = road.fingerprint()
    store.put(fp, _plan_key(0), p)
    store.put(fp, _plan_key(1), p)       # evicts 0 from memory
    assert (fp, _plan_key(0)) not in store
    p0 = store.get(fp, _plan_key(0))     # ... but disk still has it
    assert p0 is not None
    np.testing.assert_array_equal(np.asarray(p0.vals), np.asarray(p.vals))
    assert store.stats()["disk_hits"] == 1


def test_processor_borrows_plans_from_injected_store(road, tmp_path):
    store = api.PlanStore(cache_dir=str(tmp_path))
    a = api.GraphProcessor(road, b=16, num_clusters=8, store=store)
    b = api.GraphProcessor(road, b=16, num_clusters=8, store=store)
    pa = a.prepare("min_plus")
    pb = b.prepare("min_plus")
    assert pa is pb                      # one build, shared across sessions
    assert a._prepare_calls == 1 and b._prepare_calls == 0
    assert store.stats()["mem_hits"] == 1


# ---------------------------------------------------------------------------
# GraphService: registry, warm restart, coalescing
# ---------------------------------------------------------------------------


def test_service_registry_lifecycle(road):
    svc = api.GraphService()
    svc.register("roads", road, b=16, num_clusters=8)
    assert "roads" in svc and svc.graphs() == ["roads"]
    # idempotent re-register of the identical graph
    assert svc.register("roads", road, b=16, num_clusters=8) is \
        svc.get("roads")
    other = G.road_network(6, seed=3)
    with pytest.raises(ValueError, match="evict"):
        svc.register("roads", other)
    with pytest.raises(KeyError, match="no graph registered"):
        svc.get("nope")
    svc.evict("roads")
    assert "roads" not in svc


def test_service_warm_restart_skips_compile_pipeline(road, tmp_path,
                                                     monkeypatch):
    cache = str(tmp_path / "plans")
    svc = api.GraphService(cache_dir=cache)
    svc.register("roads", road, b=16, num_clusters=8)
    r1 = svc.run("roads", api.QuerySpec(algo="sssp", sources=(0,)))

    # a fresh service ("new process") must serve its first query purely
    # from the on-disk plan — zero clustering / BSR-build work
    def boom(*a, **kw):
        raise AssertionError("compile pipeline ran on a warm restart")
    monkeypatch.setattr(eng, "prepare", boom)
    svc2 = api.GraphService(cache_dir=cache)
    proc2 = svc2.register("roads", road, b=16, num_clusters=8)
    r2 = svc2.run("roads", api.QuerySpec(algo="sssp", sources=(0,)))
    assert proc2._prepare_calls == 0
    assert svc2.store.stats()["disk_hits"] == 1
    np.testing.assert_array_equal(r1.values, r2.values)
    np.testing.assert_allclose(r2.values, O.sssp_oracle(road, 0),
                               rtol=1e-5, atol=1e-4)


def test_gather_coalesces_and_matches_sequential_runs(road):
    svc = api.GraphService()
    svc.register("roads", road, b=16, num_clusters=8)
    sssp_srcs = [0, 3, 7, 11]
    bfs_srcs = [0, 9]
    tickets = {}
    for s in sssp_srcs:
        tickets[("sssp", s)] = svc.submit(
            "roads", api.QuerySpec(algo="sssp", sources=(s,)))
    for s in bfs_srcs:
        tickets[("bfs", s)] = svc.submit(
            "roads", api.QuerySpec(algo="bfs", sources=(s,)))
    t_pr = svc.submit("roads", api.QuerySpec(algo="pagerank"))
    out = svc.gather()
    assert set(out) == set(tickets.values()) | {t_pr}
    # coalesced values are bit-identical to individual run() calls
    for (algo, s), t in tickets.items():
        solo = svc.run("roads", api.QuerySpec(algo=algo, sources=(s,)))
        np.testing.assert_array_equal(out[t].values, solo.values)
        assert out[t].extra["coalesced"] == \
            {"sssp": len(sssp_srcs), "bfs": len(bfs_srcs)}[algo]
        assert out[t].extra["src"] == s
    np.testing.assert_allclose(
        out[t_pr].values, O.pagerank_oracle(road, tol=1e-12), atol=1e-5)
    st = svc.stats()
    assert st["coalesced_queries"] == len(sssp_srcs) + len(bfs_srcs)
    assert st["batched_runs"] == 2        # one wave per algorithm
    assert st["pending"] == 0


def test_gather_respects_max_wave_and_policy_grouping(road):
    svc = api.GraphService(max_wave=2)
    svc.register("roads", road, b=16, num_clusters=8)
    sync = api.ExecutionPolicy(mode="sync", max_sweeps=100_000)
    t = [svc.submit("roads", api.QuerySpec(algo="sssp", sources=(s,)))
         for s in (0, 3, 7)]                      # waves of 2 then 1
    t_sync = svc.submit("roads", api.QuerySpec(algo="sssp", sources=(5,),
                                               policy=sync))
    out = svc.gather()
    for ti, s in zip(t, (0, 3, 7)):
        np.testing.assert_allclose(out[ti].values, O.sssp_oracle(road, s),
                                   rtol=1e-5, atol=1e-4)
    # different policy → its own (singleton) group, run directly
    np.testing.assert_allclose(out[t_sync].values, O.sssp_oracle(road, 5),
                               rtol=1e-5, atol=1e-4)
    assert out[t_sync].stats.mode == "sync"
    assert svc.stats()["coalesced_queries"] == 2  # only the first wave


def test_submit_unknown_graph_fails_fast(road):
    """Regression: an unregistered name must raise a clear KeyError at
    submit() time — not surface later as a dead ticket at gather()."""
    svc = api.GraphService()
    svc.register("roads", road, b=16, num_clusters=8)
    with pytest.raises(KeyError, match="no graph registered as 'ghost'"):
        svc.submit("ghost", api.QuerySpec(algo="sssp", sources=(0,)))
    assert svc.stats()["pending"] == 0    # nothing was queued
    assert svc.gather() == {}             # and gather has nothing to say


def test_plan_store_stats_split_memory_vs_disk_tiers(road, tmp_path):
    """stats() reports per-tier hit counters AND rates: a memory hit is
    free, a disk hit still pays a deserialize."""
    proc = api.GraphProcessor(road, b=16, num_clusters=8)
    p = proc.prepare("min_plus")
    store = api.PlanStore(max_bytes=int(p.nbytes * 1.5),
                          cache_dir=str(tmp_path))
    fp = road.fingerprint()
    store.put(fp, _plan_key(0), p)
    store.get(fp, _plan_key(0))          # memory hit
    store.put(fp, _plan_key(1), p)       # evicts 0 to disk-only
    store.get(fp, _plan_key(0))          # disk hit
    store.get(fp, _plan_key(9))          # miss
    st = store.stats()
    assert st["mem_hits"] == 1 and st["disk_hits"] == 1
    assert st["misses"] == 1
    assert st["mem_hit_rate"] == pytest.approx(1 / 3)
    assert st["disk_hit_rate"] == pytest.approx(1 / 3)
    assert st["hit_rate"] == pytest.approx(2 / 3)


def test_submit_validates_spec_so_bad_requests_cannot_poison_a_batch(road):
    svc = api.GraphService()
    svc.register("roads", road, b=16, num_clusters=8)
    with pytest.raises(ValueError, match="source"):
        svc.submit("roads", api.QuerySpec(algo="sssp"))
    with pytest.raises(ValueError, match="unknown algorithm"):
        svc.submit("roads", api.QuerySpec(algo="warp", sources=(0,)))
    with pytest.raises(TypeError):  # unknown policy field
        svc.submit("roads", api.QuerySpec(algo="sssp", sources=(0,),
                                          params={"warp_speed": 9}))
    assert svc.stats()["pending"] == 0


def test_gather_isolates_runtime_failures_per_ticket(road, monkeypatch):
    """A query that fails at run time maps its ticket to the exception;
    every other ticket in the same gather still gets its Result."""
    svc = api.GraphService()
    proc = svc.register("roads", road, b=16, num_clusters=8)
    t_ok = svc.submit("roads", api.QuerySpec(algo="pagerank"))
    t_bad = svc.submit("roads", api.QuerySpec(algo="cc"))
    real_run = proc.run

    def flaky(spec):
        if spec.algo == "cc":
            raise RuntimeError("engine fell over")
        return real_run(spec)
    monkeypatch.setattr(proc, "run", flaky)
    out = svc.gather()
    assert isinstance(out[t_bad], RuntimeError)
    np.testing.assert_allclose(
        out[t_ok].values, O.pagerank_oracle(road, tol=1e-12), atol=1e-5)


def test_evict_resolves_pending_tickets_instead_of_dropping_them(road):
    svc = api.GraphService()
    svc.register("roads", road, b=16, num_clusters=8)
    svc.register("keep", G.road_network(6, seed=3), b=16, num_clusters=4)
    t_gone = svc.submit("roads", api.QuerySpec(algo="sssp", sources=(0,)))
    t_kept = svc.submit("keep", api.QuerySpec(algo="sssp", sources=(0,)))
    svc.evict("roads")
    out = svc.gather()
    assert isinstance(out[t_gone], KeyError)         # resolved, not lost
    assert out[t_kept].stats.converged


def test_register_rejects_changed_session_parameters(road):
    svc = api.GraphService()
    svc.register("roads", road, b=16, num_clusters=8)
    with pytest.raises(ValueError, match="evict"):
        svc.register("roads", road, b=32, num_clusters=8)
    with pytest.raises(ValueError, match="evict"):
        svc.register("roads", road, b=16, num_clusters=4)


def test_plan_store_recovers_from_corrupt_disk_entries(road, tmp_path):
    """Truncated/garbage cache files (crash mid-write, disk rot) are
    dropped and rebuilt, never a permanent crash."""
    import os
    proc = api.GraphProcessor(road, b=16, num_clusters=8)
    p = proc.prepare("min_plus")
    store = api.PlanStore(cache_dir=str(tmp_path))
    fp = road.fingerprint()
    store.put(fp, _plan_key(0), p)
    (path,) = [tmp_path / f for f in os.listdir(tmp_path)]
    for garbage in (b"", b"not a zip", path.read_bytes()[:100]):
        path.write_bytes(garbage)
        fresh = api.PlanStore(cache_dir=str(tmp_path))
        assert fresh.get(fp, _plan_key(0)) is None   # dropped, no raise
        assert not path.exists()
        store.put(fp, _plan_key(0), p)               # re-persist for next


def test_plan_store_disk_write_failure_is_best_effort(road, tmp_path,
                                                      monkeypatch):
    """A full/read-only cache dir must not fail a query whose plan is
    already good in memory."""
    proc = api.GraphProcessor(road, b=16, num_clusters=8)
    p = proc.prepare("min_plus")
    store = api.PlanStore(cache_dir=str(tmp_path))

    def enospc(*a, **kw):
        raise OSError(28, "No space left on device")
    monkeypatch.setattr("builtins.open", enospc)
    store.put(road.fingerprint(), _plan_key(0), p)   # no raise
    monkeypatch.undo()
    assert store.get(road.fingerprint(), _plan_key(0)) is p
    assert store.stats()["disk_errors"] == 1


def test_plan_store_keeps_an_oversized_plan(road):
    """A single plan larger than the whole byte budget must stay
    servable (budget overshoots by one plan; no rebuild-per-query)."""
    p = api.GraphProcessor(road, b=16, num_clusters=8).prepare("min_plus")
    store = api.PlanStore(max_bytes=1)
    fp = road.fingerprint()
    store.put(fp, _plan_key(0), p)
    assert store.get(fp, _plan_key(0)) is p
    store.put(fp, _plan_key(1), p)       # newest survives, LRU evicted
    assert store.get(fp, _plan_key(1)) is p
    assert (fp, _plan_key(0)) not in store


def test_service_shares_plans_across_graph_names(road):
    """The store key is the graph *fingerprint*: the same graph
    registered under two names builds each plan once."""
    svc = api.GraphService()
    a = svc.register("a", road, b=16, num_clusters=8)
    b = svc.register("b", road, b=16, num_clusters=8)
    assert a.prepare("min_plus") is b.prepare("min_plus")
    assert svc.store.stats()["puts"] == 1


def test_gather_coalesces_distributed_policy_into_2d_batched_engine(road):
    """A wave whose resolved policy is mode='distributed' runs as ONE
    batched 2-D shard_map dispatch — not the retired per-source loop —
    and each ticket surfaces the engine's mesh/per-query sweeps."""
    dist = api.ExecutionPolicy(mode="distributed", max_sweeps=100_000)
    svc = api.GraphService(policy=dist)
    svc.register("roads", road, b=16, num_clusters=8)
    sources = (0, 3, 7)
    tickets = [svc.submit("roads", api.QuerySpec(algo="sssp",
                                                 sources=(s,)))
               for s in sources]
    out = svc.gather()
    for t, s in zip(tickets, sources):
        r = out[t]
        assert not isinstance(r, Exception), r
        assert r.extra["coalesced"] == len(sources)
        assert "batched_fallback" not in r.extra    # fallback retired
        assert r.extra["dist"].query_sweeps.shape == (len(sources),)
        solo = svc.run("roads", api.QuerySpec(algo="sssp", sources=(s,)))
        np.testing.assert_array_equal(r.values, solo.values)
    st = svc.stats()
    assert st["batched_runs"] == 1                  # one dispatch total
    assert st["coalesced_queries"] == len(sources)
