"""Distribution: sharding rules, distributed graph engine (1 and 8 fake
devices via subprocess), the 2-D ("graph", "query") batched engine
across mesh factorizations, dry-run cell smoke.

The factorization parity tests run in-process when the host already has
>= 8 devices (the CI multi-device lane sets
XLA_FLAGS=--xla_force_host_platform_device_count=8 via DEVICES=8 in
benchmarks/ci.sh) and fall back to one subprocess sweep on single-device
hosts."""

import json
import os
import subprocess
import sys
from types import SimpleNamespace

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.sharding.rules import parse_axes, spec_for

MESH = SimpleNamespace(shape={"data": 16, "model": 16})
MESH_MP = SimpleNamespace(shape={"pod": 2, "data": 16, "model": 16})


def test_spec_basic_tp_fsdp():
    assert spec_for((18432, 96, 192), "embed heads head_dim", MESH) == \
        P("data", "model", None)
    # batch spans pod+data on the multi-pod mesh
    assert spec_for((256, 4096), "batch seq", MESH_MP) == \
        P(("pod", "data"), None)


def test_spec_indivisible_falls_back_replicated():
    # 49155 vocab is indivisible by 16 → replicated
    assert spec_for((49155, 2048), "vocab embed", MESH) == \
        P(None, ("data", "model"))


def test_spec_greedy_fill_soaks_unused_axes():
    # kv_heads=8 can't take model(16); embed takes data AND model
    assert spec_for((18432, 8, 192), "embed kv_heads head_dim", MESH) == \
        P(("data", "model"), None, None)
    # but when heads CAN take model, embed only takes data
    assert spec_for((18432, 96, 192), "embed heads head_dim", MESH) == \
        P("data", "model", None)
    # embed_kv never takes model (GSPMD conflict, see rules.py)
    assert spec_for((18432, 8, 192), "embed_kv kv_heads head_dim",
                    MESH) == P("data", None, None)


def test_spec_no_axis_reuse():
    sp = spec_for((4096, 4096), "embed mlp", MESH)
    used = [a for part in sp for a in
            ((part,) if isinstance(part, str) else (part or ()))]
    assert len(used) == len(set(used))


def test_parse_axes():
    assert parse_axes("embed . heads") == ("embed", None, "heads")
    assert parse_axes("") == ()


def test_distributed_graph_engine_single_device():
    from repro.core import algorithms as A
    from repro.core import graph as G
    from repro.core import oracles as O
    from repro.core import placement as PL
    import jax.numpy as jnp

    g = G.rmat(300, 1500, seed=5)
    r = A.sssp(g, 0, mode="async", b=16, num_clusters=8)
    p = r.prepared
    x0f = np.full(g.n, np.inf, dtype=np.float32)
    x0f[0] = 0
    x0 = p.to_blocks(x0f, np.inf)
    x, ds = PL.distributed_sync_run(p, x0, "relax")
    np.testing.assert_allclose(np.asarray(x).reshape(-1)[p.perm],
                               O.sssp_oracle(g, 0), rtol=1e-5, atol=1e-4)
    assert ds.converged
    _ = jnp


def test_make_graph_mesh_is_2d_and_degenerates():
    from repro.core import placement as PL
    mesh = PL.make_graph_mesh(1)
    assert dict(mesh.shape) == {"graph": 1, "query": 1}
    with pytest.raises(ValueError):
        PL.make_graph_mesh(1, 0)
    with pytest.raises(ValueError):
        PL.make_graph_mesh(4, 3)   # 3 does not divide 4


def test_factor_query_axis():
    from repro.core import placement as PL
    assert PL.factor_query_axis(8, 1) == 1
    assert PL.factor_query_axis(8, 3) == 2    # largest divisor <= 3
    assert PL.factor_query_axis(8, 5) == 4
    assert PL.factor_query_axis(8, 64) == 8
    assert PL.factor_query_axis(1, 64) == 1
    assert PL.factor_query_axis(6, 4) == 3


def test_batched_engine_rejects_query_axis_0():
    """The query_axis=0 per-source escape hatch is the session API's —
    the engine must refuse it rather than silently auto-factor."""
    from repro.core import placement as PL
    p, x0, _ = _batched_fixture("min_plus")
    with pytest.raises(ValueError, match="query_axis"):
        PL.distributed_sync_run_batched(p, x0, query_axis=0)


def _batched_fixture(semiring):
    """(Prepared, stacked x0, sync-batched reference) for one semiring."""
    from repro.core import engine as eng
    from repro.core import graph as G

    g = G.rmat(200, 900, seed=6)
    sources = [0, 5, 9, 13, 17]
    p = eng.prepare(g, semiring, b=8, num_clusters=8)
    if semiring == "max_min":
        def x0f(s):
            x = np.zeros(g.n, dtype=np.float32)
            x[s] = 1.0
            return np.asarray(p.to_blocks(x, 0.0))
    else:
        def x0f(s):
            x = np.full(g.n, np.inf, dtype=np.float32)
            x[s] = 0.0
            return np.asarray(p.to_blocks(x, np.inf))
    x0 = np.stack([x0f(s) for s in sources])
    ref, _ = eng.run_sync_batched(p, x0, max_sweeps=100_000)
    return p, x0, np.asarray(ref)


# (num_devices, query_axis) — the factorizations the issue names
FACTORIZATIONS = [(1, 1), (4, 2), (8, 1), (8, 8)]


@pytest.mark.parametrize("semiring", ["min_plus", "max_min"])
@pytest.mark.parametrize("ndev,qaxis", FACTORIZATIONS)
def test_batched_distributed_parity_across_factorizations(
        semiring, ndev, qaxis):
    """Batched-distributed == run_sync_batched, BIT-identical, on every
    mesh factorization (1×1, 2×2, 8×1, 1×8).  Needs the multi-device
    lane's fake-device grid for the non-trivial meshes."""
    if len(jax.devices()) < ndev:
        pytest.skip(f"needs {ndev} devices (CI multi-device lane); "
                    f"have {len(jax.devices())} — subprocess test "
                    "covers this elsewhere")
    from repro.core import placement as PL
    p, x0, ref = _batched_fixture(semiring)
    mesh = PL.make_graph_mesh(ndev, qaxis)
    x, ds = PL.distributed_sync_run_batched(p, x0, "relax",
                                            max_sweeps=100_000, mesh=mesh)
    assert np.array_equal(np.asarray(x), ref)
    assert ds.converged
    assert ds.mesh_shape == (ndev // qaxis, qaxis)
    assert ds.query_sweeps.shape == (x0.shape[0],)
    assert ds.sweeps == int(ds.query_sweeps.max())


_SUBPROCESS_8DEV = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
from repro.core import algorithms as A, engine as E, graph as G, \
    oracles as O, placement as PL
g = G.rmat(200, 900, seed=6)
r = A.sssp(g, 0, mode="async", b=8, num_clusters=8)
p = r.prepared
x0f = np.full(g.n, np.inf, dtype=np.float32); x0f[0] = 0
x0 = p.to_blocks(x0f, np.inf)
mesh = PL.make_graph_mesh(8)
x, ds = PL.distributed_sync_run(p, x0, "relax", mesh=mesh)
got = np.asarray(x).reshape(-1)[p.perm]
np.testing.assert_allclose(got, O.sssp_oracle(g, 0), rtol=1e-5, atol=1e-4)
low = PL.lower_distributed(p, mesh)
txt = low.compile().as_text()
assert "all-gather" in txt or "all-reduce" in txt, "no collectives?"
print("OK8")

# 2-D batched engine: bit-identical to the vmap sync oracle on every
# factorization of the 8 fake devices (1x1, 2x2, 8x1, 1x8)
sources = [0, 5, 9, 13, 17]
X0 = np.stack([np.asarray(p.to_blocks(
    np.where(np.arange(g.n) == s, 0, np.inf).astype(np.float32),
    np.inf)) for s in sources])
ref, _ = E.run_sync_batched(p, X0, max_sweeps=100_000)
ref = np.asarray(ref)
for nd, qa in [(1, 1), (4, 2), (8, 1), (8, 8)]:
    m2 = PL.make_graph_mesh(nd, qa)
    xb, db = PL.distributed_sync_run_batched(
        p, X0, "relax", max_sweeps=100_000, mesh=m2)
    assert np.array_equal(np.asarray(xb), ref), (nd, qa)
    assert db.converged and db.mesh_shape == (nd // qa, qa)
low_b = PL.lower_distributed(p, PL.make_graph_mesh(8, 4), batch=len(sources))
txt_b = low_b.compile().as_text()
assert "all-gather" in txt_b or "all-reduce" in txt_b, "no collectives?"
print("OK8-2D")
"""


def test_distributed_graph_engine_8_fake_devices():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", _SUBPROCESS_8DEV],
                         capture_output=True, text=True, env=env,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))), timeout=600)
    assert "OK8" in out.stdout and "OK8-2D" in out.stdout, \
        out.stderr[-2000:]


def test_dryrun_single_cell_subprocess():
    """One real dry-run cell end-to-end (whisper decode: cheapest)."""
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "whisper-tiny", "--shape", "decode_32k", "--no-pieces"],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=900)
    assert "ok" in out.stdout and "0 errors" in out.stdout, \
        out.stdout + out.stderr[-2000:]


def test_dryrun_results_if_present():
    """Validate the committed sweep results when available: every cell is
    ok or a documented skip."""
    base = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "results")
    for sub in ("dryrun_single", "dryrun_multi"):
        d = os.path.join(base, sub)
        if not os.path.isdir(d):
            pytest.skip("sweep results not present")
        cells = []
        for name in os.listdir(d):
            with open(os.path.join(d, name)) as f:
                cells.append(json.load(f))
        assert len(cells) >= 40
        bad = [c for c in cells if c["status"] == "error"]
        assert not bad, [(c["arch"], c["shape"], c["error"]) for c in bad]
