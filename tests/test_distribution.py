"""Distribution: sharding rules, distributed graph engine (1 and 8 fake
devices via subprocess), dry-run cell smoke."""

import json
import os
import subprocess
import sys
from types import SimpleNamespace

import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.sharding.rules import parse_axes, spec_for

MESH = SimpleNamespace(shape={"data": 16, "model": 16})
MESH_MP = SimpleNamespace(shape={"pod": 2, "data": 16, "model": 16})


def test_spec_basic_tp_fsdp():
    assert spec_for((18432, 96, 192), "embed heads head_dim", MESH) == \
        P("data", "model", None)
    # batch spans pod+data on the multi-pod mesh
    assert spec_for((256, 4096), "batch seq", MESH_MP) == \
        P(("pod", "data"), None)


def test_spec_indivisible_falls_back_replicated():
    # 49155 vocab is indivisible by 16 → replicated
    assert spec_for((49155, 2048), "vocab embed", MESH) == \
        P(None, ("data", "model"))


def test_spec_greedy_fill_soaks_unused_axes():
    # kv_heads=8 can't take model(16); embed takes data AND model
    assert spec_for((18432, 8, 192), "embed kv_heads head_dim", MESH) == \
        P(("data", "model"), None, None)
    # but when heads CAN take model, embed only takes data
    assert spec_for((18432, 96, 192), "embed heads head_dim", MESH) == \
        P("data", "model", None)
    # embed_kv never takes model (GSPMD conflict, see rules.py)
    assert spec_for((18432, 8, 192), "embed_kv kv_heads head_dim",
                    MESH) == P("data", None, None)


def test_spec_no_axis_reuse():
    sp = spec_for((4096, 4096), "embed mlp", MESH)
    used = [a for part in sp for a in
            ((part,) if isinstance(part, str) else (part or ()))]
    assert len(used) == len(set(used))


def test_parse_axes():
    assert parse_axes("embed . heads") == ("embed", None, "heads")
    assert parse_axes("") == ()


def test_distributed_graph_engine_single_device():
    from repro.core import algorithms as A
    from repro.core import graph as G
    from repro.core import oracles as O
    from repro.core import placement as PL
    import jax.numpy as jnp

    g = G.rmat(300, 1500, seed=5)
    r = A.sssp(g, 0, mode="async", b=16, num_clusters=8)
    p = r.prepared
    x0f = np.full(g.n, np.inf, dtype=np.float32)
    x0f[0] = 0
    x0 = p.to_blocks(x0f, np.inf)
    x, ds = PL.distributed_sync_run(p, x0, "relax")
    np.testing.assert_allclose(np.asarray(x).reshape(-1)[p.perm],
                               O.sssp_oracle(g, 0), rtol=1e-5, atol=1e-4)
    assert ds.converged
    _ = jnp


_SUBPROCESS_8DEV = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
from repro.core import algorithms as A, graph as G, oracles as O, placement as PL
g = G.rmat(200, 900, seed=6)
r = A.sssp(g, 0, mode="async", b=8, num_clusters=8)
p = r.prepared
x0f = np.full(g.n, np.inf, dtype=np.float32); x0f[0] = 0
x0 = p.to_blocks(x0f, np.inf)
mesh = PL.make_graph_mesh(8)
x, ds = PL.distributed_sync_run(p, x0, "relax", mesh=mesh)
got = np.asarray(x).reshape(-1)[p.perm]
np.testing.assert_allclose(got, O.sssp_oracle(g, 0), rtol=1e-5, atol=1e-4)
low = PL.lower_distributed(p, mesh)
txt = low.compile().as_text()
assert "all-gather" in txt or "all-reduce" in txt, "no collectives?"
print("OK8")
"""


def test_distributed_graph_engine_8_fake_devices():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", _SUBPROCESS_8DEV],
                         capture_output=True, text=True, env=env,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))), timeout=600)
    assert "OK8" in out.stdout, out.stderr[-2000:]


def test_dryrun_single_cell_subprocess():
    """One real dry-run cell end-to-end (whisper decode: cheapest)."""
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "whisper-tiny", "--shape", "decode_32k", "--no-pieces"],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=900)
    assert "ok" in out.stdout and "0 errors" in out.stdout, \
        out.stdout + out.stderr[-2000:]


def test_dryrun_results_if_present():
    """Validate the committed sweep results when available: every cell is
    ok or a documented skip."""
    base = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "results")
    for sub in ("dryrun_single", "dryrun_multi"):
        d = os.path.join(base, sub)
        if not os.path.isdir(d):
            pytest.skip("sweep results not present")
        cells = []
        for name in os.listdir(d):
            with open(os.path.join(d, name)) as f:
                cells.append(json.load(f))
        assert len(cells) >= 40
        bad = [c for c in cells if c["status"] == "error"]
        assert not bad, [(c["arch"], c["shape"], c["error"]) for c in bad]
