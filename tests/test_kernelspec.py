"""KernelSpec policy surface: validation, dispatch registry, the
deprecated ``impl="pallas"`` spelling, engine-level fused-vs-ref
identity, and measured-tuning determinism + PlanStore persistence."""

import dataclasses
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core import engine as eng
from repro.core import graph as G
from repro.kernels import ops
from repro.kernels import autotune as at
from repro.kernels.spec import KernelSpec, as_kernel_spec

FUSED = KernelSpec(impl="pallas", fuse_frontier=True)


@pytest.fixture(scope="module")
def graph():
    return G.erdos(200, 0.03, seed=2, weighted=True)


@pytest.fixture(scope="module")
def proc(graph):
    return api.GraphProcessor(graph, b=16, num_clusters=16)


# -- KernelSpec validation --------------------------------------------------

def test_spec_rejects_ref_with_pallas_knobs():
    with pytest.raises(ValueError, match="impl='pallas'"):
        KernelSpec(impl="ref", block_size=8)
    with pytest.raises(ValueError, match="impl='pallas'"):
        KernelSpec(impl="ref", fuse_frontier=True)
    with pytest.raises(ValueError, match="impl='pallas'"):
        KernelSpec(impl="ref", autotune=True)


def test_spec_rejects_incoherent_combos():
    with pytest.raises(ValueError, match="one of"):
        KernelSpec(impl="mosaic")
    with pytest.raises(ValueError, match="positive int"):
        KernelSpec(impl="pallas", block_size=0)
    with pytest.raises(ValueError, match="rows_per_step"):
        KernelSpec(impl="pallas", fuse_frontier=True, rows_per_step=2)
    with pytest.raises(ValueError, match="nothing to tune"):
        KernelSpec(impl="pallas", autotune=True, block_size=8,
                   rows_per_step=2)
    with pytest.raises(ValueError, match="nothing to tune"):
        KernelSpec(impl="pallas", autotune=True, fuse_frontier=True,
                   block_size=8)


def test_spec_concrete_fills_knobs():
    s = KernelSpec(impl="pallas", autotune=True)
    c = s.concrete({"block_size": 4, "rows_per_step": 2})
    assert (c.block_size, c.rows_per_step, c.autotune) == (4, 2, False)
    assert KernelSpec(impl="pallas").concrete() == KernelSpec(
        impl="pallas", block_size=8, rows_per_step=1)
    f = FUSED.concrete({"block_size": 16, "rows_per_step": 4})
    assert (f.block_size, f.rows_per_step) == (16, 1)  # fused pins rs=1


def test_as_kernel_spec_coercions():
    assert as_kernel_spec(None) == KernelSpec()
    assert as_kernel_spec("pallas") == KernelSpec(impl="pallas")
    assert as_kernel_spec(FUSED) is FUSED
    with pytest.raises(TypeError):
        as_kernel_spec(42)


# -- dispatch registry ------------------------------------------------------

def test_select_kernel_registry():
    assert callable(ops.select_kernel("bsr_spmv", KernelSpec()))
    assert callable(ops.select_kernel("bsr_spmv", FUSED))
    with pytest.raises(KeyError, match="registered"):
        ops.select_kernel("conv2d", KernelSpec())
    with pytest.raises(KeyError, match="registered"):
        # attention has no fused variant; the registry fails loudly
        # instead of silently dropping the fuse_frontier request
        ops.select_kernel("attention", FUSED)


def test_platform_guard():
    assert ops.use_interpret("cpu") and not ops.use_interpret("tpu")


# -- ExecutionPolicy surface ------------------------------------------------

def test_impl_pallas_deprecated_but_equal():
    with pytest.warns(DeprecationWarning, match="KernelSpec"):
        old = api.ExecutionPolicy(impl="pallas")
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        new = api.ExecutionPolicy(kernel=KernelSpec(impl="pallas"))
        ref = api.ExecutionPolicy(impl="ref")
        dflt = api.ExecutionPolicy()
    assert old == new and old.kernel == KernelSpec(impl="pallas")
    assert ref == dflt and dflt.kernel == KernelSpec(impl="ref")


def test_policy_rejects_conflicts():
    with pytest.raises(ValueError):
        api.ExecutionPolicy(impl="ref", kernel=KernelSpec(impl="pallas"))
    with pytest.raises(ValueError, match="distributed"):
        api.ExecutionPolicy(mode="distributed",
                            kernel=KernelSpec(impl="pallas"))


def test_policy_but_rederives_the_other_spelling():
    pol = api.ExecutionPolicy(kernel=KernelSpec(impl="pallas",
                                                block_size=4))
    assert pol.but(impl="ref").kernel == KernelSpec(impl="ref")
    assert api.ExecutionPolicy().but(kernel=FUSED).impl == "pallas"
    assert pol.but(tol=1e-3).kernel == pol.kernel  # untouched knobs ride


# -- engine-level fused vs ref identity -------------------------------------

@pytest.mark.parametrize("mode", ["sync", "async"])
@pytest.mark.parametrize("algo", ["sssp", "bfs", "reachability", "cc"])
def test_engine_fused_bit_identical(proc, mode, algo, rng):
    pol = api.ExecutionPolicy(mode=mode, max_sweeps=10_000)
    polf = pol.but(kernel=FUSED)
    run = {"sssp": lambda pl: proc.sssp(3, policy=pl),
           "bfs": lambda pl: proc.bfs(3, policy=pl),
           "reachability": lambda pl: proc.reachability(3, policy=pl),
           "cc": lambda pl: proc.connected_components(policy=pl)}[algo]
    r0, r1 = run(pol), run(polf)
    np.testing.assert_array_equal(r0.values, r1.values)
    assert r0.stats.sweeps == r1.stats.sweeps
    assert r0.stats.converged and r1.stats.converged


@pytest.mark.parametrize("mode", ["sync", "async"])
def test_engine_fused_pagerank(proc, mode):
    # plus_times accumulates in a different grouping inside the fused
    # kernel; over a full damped-iteration trajectory the drift stays
    # below the convergence tolerance but is not bitwise
    pol = api.ExecutionPolicy(mode=mode)
    r0 = proc.pagerank(policy=pol)
    r1 = proc.pagerank(policy=pol.but(kernel=FUSED))
    np.testing.assert_allclose(r0.values, r1.values, atol=1e-6)
    assert r0.stats.sweeps == r1.stats.sweeps


def test_engine_fused_batched(proc):
    pol = api.ExecutionPolicy(mode="sync", max_sweeps=10_000)
    r0 = proc.sssp(sources=[0, 5, 9], policy=pol)
    r1 = proc.sssp(sources=[0, 5, 9], policy=pol.but(kernel=FUSED))
    np.testing.assert_array_equal(r0.values, r1.values)
    assert r0.stats.sweeps == r1.stats.sweeps


def test_fused_all_converged_early_exit(graph):
    """A dead frontier must cost exactly one (empty) sweep and pass the
    state through untouched."""
    p = eng.prepare(graph, "min_plus", b=16, num_clusters=16)
    x0 = p.to_blocks(np.zeros(graph.n, np.float32), 0.0)
    x, stats = eng.run_sync(p, x0, "relax", kernel=FUSED.concrete(),
                            changed0=jnp.zeros(p.r_pad, bool))
    assert stats.sweeps == 1 and stats.converged
    np.testing.assert_array_equal(np.asarray(x), np.asarray(x0))
    assert stats.tile_work == 0.0


# -- measured autotuner -----------------------------------------------------

def _fake_measure(calls):
    def measure(call, config, iters):
        calls.append(config)
        # deterministic synthetic cost: favour bk=4, rs=2
        return (abs(config.block_size - 4) + 1) * \
            (abs((config.rows_per_step or 1) - 2) + 1) * 1e-6
    return measure


def test_autotune_deterministic(proc):
    p = proc.prepare("min_plus")
    spec = KernelSpec(impl="pallas", autotune=True)
    calls = []
    rec1 = at.autotune_spmv(p, spec, seed=0, measure=_fake_measure(calls))
    rec2 = at.autotune_spmv(p, spec, seed=0, measure=_fake_measure([]))
    assert rec1 == rec2
    assert (rec1["block_size"], rec1["rows_per_step"]) == (4, 2)
    assert rec1["seed"] == 0
    assert len(calls) == len(rec1["candidates"])
    assert rec1["modeled_s"] > 0 and rec1["measured_s"] > 0
    # pinned fields shrink the sweep
    pinned = at.autotune_spmv(
        p, KernelSpec(impl="pallas", autotune=True, block_size=8),
        seed=0, measure=_fake_measure([]))
    assert all(c["block_size"] == 8 for c in pinned["candidates"])
    with pytest.raises(ValueError):
        at.autotune_spmv(p, KernelSpec(impl="ref"), seed=0)


def test_autotune_cached_per_plan(graph):
    proc = api.GraphProcessor(graph, b=16, num_clusters=16)
    spec = KernelSpec(impl="pallas", fuse_frontier=True, autotune=True)
    pol = api.ExecutionPolicy(mode="sync", kernel=spec)
    r1 = proc.sssp(3, policy=pol)
    r2 = proc.sssp(5, policy=pol)
    info = proc.cache_info()
    assert info["autotune_calls"] == 1 and info["tunings"] == 1
    # tuning must not change results vs the untuned fused path
    r0 = proc.sssp(3, policy=api.ExecutionPolicy(mode="sync"))
    np.testing.assert_array_equal(r0.values, r1.values)
    assert r2.stats.converged


def test_tunings_survive_plan_store_restart(graph, tmp_path):
    spec = KernelSpec(impl="pallas", autotune=True)
    pol = api.ExecutionPolicy(mode="sync", kernel=spec)

    svc = api.GraphService(cache_dir=str(tmp_path))
    proc = svc.register("g", graph, b=16, num_clusters=16)
    proc.sssp(3, policy=pol)
    assert proc.cache_info()["autotune_calls"] == 1
    assert svc.store.stats()["tunings"] == 1

    # cold process, same cache_dir: tuning record comes off disk, the
    # calibration sweep is NOT re-run
    svc2 = api.GraphService(cache_dir=str(tmp_path))
    assert svc2.store.stats()["tunings"] == 1
    proc2 = svc2.register("g", graph, b=16, num_clusters=16)
    r = proc2.sssp(3, policy=pol)
    assert proc2.cache_info()["autotune_calls"] == 0
    assert r.stats.converged

    key = proc2.plan_key("min_plus")
    tkey = dataclasses.replace(key, kernel=spec)
    rec = svc2.store.get_tuning(graph.fingerprint(), tkey)
    assert rec is not None and rec["block_size"] >= 1
