"""Serving: static generation, continuous batching, and internals
(ring-buffer local attention, RWKV/Griffin chunked scans)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import griffin, layers, lm, rwkv
from repro.serve.engine import Request, ServeLoop, generate


def test_generate_shapes(rng):
    cfg = get_config("granite-3-2b").reduced()
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    prompts = jnp.asarray(rng.integers(2, cfg.vocab_size, (3, 8)),
                          jnp.int32)
    toks = generate(cfg, params, prompts, max_new_tokens=5)
    assert toks.shape == (3, 13)
    np.testing.assert_array_equal(toks[:, :8], np.asarray(prompts))


def test_serve_loop_matches_static(rng):
    cfg = get_config("granite-3-2b").reduced()
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    prompts = rng.integers(2, cfg.vocab_size, (2, 8)).astype(np.int32)
    static = generate(cfg, params, jnp.asarray(prompts),
                      max_new_tokens=6)
    sl = ServeLoop(cfg, params, num_slots=3, cache_len=32)
    reqs = [Request(rid=i, prompt=prompts[i], max_new=6)
            for i in range(2)]
    for r in reqs:
        sl.submit(r)
    sl.run()
    for i, r in enumerate(reqs):
        assert r.generated == static[i, 8:].tolist()


def test_serve_loop_oversubscribed(rng):
    cfg = get_config("granite-3-2b").reduced()
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    sl = ServeLoop(cfg, params, num_slots=2, cache_len=24)
    reqs = [Request(rid=i,
                    prompt=rng.integers(2, cfg.vocab_size, 6).astype(
                        np.int32), max_new=4) for i in range(5)]
    for r in reqs:
        sl.submit(r)
    sl.run()
    assert all(r.done for r in reqs)
    assert all(len(r.generated) == 4 for r in reqs)


def test_rwkv_chunked_scan_matches_plain(rng):
    """TIME_CHUNK remat path == plain scan (bitwise-ish)."""
    b, s, h, hs = 2, rwkv.TIME_CHUNK * 2, 2, 8
    r = jnp.asarray(rng.standard_normal((b, s, h, hs)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, h, hs)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, h, hs)), jnp.float32)
    w = jnp.asarray(rng.random((b, s, h, hs)) * 0.5 + 0.4, jnp.float32)
    u = jnp.asarray(rng.standard_normal((h, hs)), jnp.float32)
    st0 = jnp.zeros((b, h, hs, hs), jnp.float32)
    y1, st1 = rwkv._wkv_scan(r, k, v, w, u, st0)
    # plain path via a sequence length that bypasses chunking
    ys, sts = [], st0
    for c in range(2):
        sl = slice(c * rwkv.TIME_CHUNK, (c + 1) * rwkv.TIME_CHUNK)
        yc, sts = rwkv._wkv_scan(r[:, sl], k[:, sl], v[:, sl], w[:, sl],
                                 u, sts)
        ys.append(yc)
    y2 = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(st1), np.asarray(sts),
                               rtol=2e-4, atol=2e-4)


def test_griffin_conv_state_continuity(rng):
    """Chunked conv+LRU over two chunks == one pass over the full seq."""
    cfg = dataclasses.replace(get_config("recurrentgemma-9b").reduced(),
                              compute_dtype="float32")
    p, _ = griffin.recurrent_init(cfg, jax.random.PRNGKey(0))
    b, s = 2, 24
    x = jnp.asarray(rng.standard_normal((b, s, cfg.d_model)), jnp.float32)
    st = griffin.recurrent_state_init(cfg, b)
    y_full, _ = griffin.recurrent_apply(cfg, p, x, st)
    st2 = griffin.recurrent_state_init(cfg, b)
    y1, st2 = griffin.recurrent_apply(cfg, p, x[:, :12], st2)
    y2, _ = griffin.recurrent_apply(cfg, p, x[:, 12:], st2)
    np.testing.assert_allclose(np.asarray(y_full),
                               np.asarray(jnp.concatenate([y1, y2], 1)),
                               rtol=2e-4, atol=2e-4)


def test_local_ring_buffer_decode_matches_windowed(rng):
    """Ring-buffer decode == full-cache decode with window mask, once the
    context exceeds the window."""
    cfg = dataclasses.replace(get_config("recurrentgemma-9b").reduced(),
                              compute_dtype="float32", window=8)
    p, _ = layers.attn_init(cfg, jax.random.PRNGKey(0))
    b, s = 1, 20
    x = jnp.asarray(rng.standard_normal((b, s, cfg.d_model)), jnp.float32)
    positions = jnp.arange(s)[None, :]
    # ground truth: full-sequence local attention last-token output
    full = layers.attn_apply(cfg, p, x, positions=positions,
                             window=cfg.window)
    # ring path: prefill s-1 then decode token s-1
    from repro.models.lm import _local_decode, _local_prefill
    _, cache = _local_prefill(cfg, p, x[:, :-1], positions[:, :-1], "ref")
    out, _ = _local_decode(cfg, p, x[:, -1:], cache, jnp.int32(s - 1))
    np.testing.assert_allclose(np.asarray(out[:, 0]),
                               np.asarray(full[:, -1]), rtol=2e-3,
                               atol=2e-3)


def test_rope_preserves_norm_and_relativity(rng):
    x = jnp.asarray(rng.standard_normal((1, 6, 2, 16)), jnp.float32)
    pos = jnp.arange(6)[None, :]
    y = layers.apply_rope(x, pos, theta=10_000.0)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(x), axis=-1),
                               np.linalg.norm(np.asarray(y), axis=-1),
                               rtol=1e-5)
    # relative property: <q_i, k_j> depends only on i-j
    q = jnp.asarray(rng.standard_normal((1, 8, 1, 16)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 8, 1, 16)), jnp.float32)
    qa = layers.apply_rope(jnp.tile(q[:, :1], (1, 8, 1, 1)),
                           jnp.arange(8)[None, :], 100.0)
    ka = layers.apply_rope(jnp.tile(k[:, :1], (1, 8, 1, 1)),
                           jnp.arange(8)[None, :], 100.0)
    dots = np.asarray(jnp.einsum("bshd,bthd->bst", qa, ka))[0]
    for d in range(1, 4):
        diag = np.diagonal(dots, offset=d)
        np.testing.assert_allclose(diag, diag[0], rtol=1e-4, atol=1e-4)


def test_partial_rope_leaves_tail_untouched(rng):
    x = jnp.asarray(rng.standard_normal((1, 4, 1, 16)), jnp.float32)
    y = layers.apply_rope(x, jnp.arange(4)[None, :], 1e4, fraction=0.5)
    np.testing.assert_array_equal(np.asarray(x[..., 8:]),
                                  np.asarray(y[..., 8:]))
    assert not np.allclose(np.asarray(x[..., :8]), np.asarray(y[..., :8]))
